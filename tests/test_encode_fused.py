"""Fused transmit-side encode (one-pass split+pack): bit-parity with the
legacy three-pass composition, ragged-tile Pallas dispatch, round-trip
through the fused receive, policy/plan threading, and fallback accounting.

The parity oracle everywhere is the EXISTING composition —
``codec.split_planes`` + ``packing.bitplane_pack`` +
``packing.pack_exponents`` — which the fused dispatch must reproduce
field-by-field at the bit level, including both legacy padding modes
(exponent edge-pad to the block, lo zero-pad to the group) on ragged
shapes.  8-device plan parity lives in tests/drivers/multidev.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st  # hypothesis or fallback

from repro import kernels
from repro.core import codec, packing
from repro.core import compressed_collectives as cc
from repro.core import policy as policy_lib
from repro.core.policy import CompressionPolicy
from repro.kernels import ops, ref
from repro.kernels.encode_fused import TILE_B

TILE = 512 * TILE_B  # elements per kernel grid step


def legacy_wire(x, width, block=512, exc_frac=0.02):
    """The unfused composition the fused encode must match bitwise."""
    lay = codec.layout_of(x.dtype)
    exp, lo = codec.split_planes(x)
    lo_planes = packing.bitplane_pack(
        packing._pad_to(lo.astype(jnp.uint32), packing.GROUP, "zero"),
        lay.lo_bits)
    pk = packing.pack_exponents(exp, width=width, block=block,
                                exc_frac=exc_frac)
    return {"lo": lo_planes, "payload": pk.payload, "bases": pk.bases,
            "exc_idx": pk.exc_idx, "exc_raw": pk.exc_raw,
            "overflow": pk.overflow}


def assert_wire_equal(got, want, ctx=""):
    for k in want:
        assert got[k].dtype == want[k].dtype, (ctx, k)
        assert got[k].shape == want[k].shape, (ctx, k)
        assert bool(jnp.all(got[k] == want[k])), (ctx, k)


def make_input(dt_name, n, seed=0, zeros=0.08, poison=True):
    lay = codec.LAYOUTS[dt_name]
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 0.02, n)
    x[rng.random(n) < zeros] = 0.0  # exercise the zero escape
    if poison and n > 128:  # force exception blocks
        x[n // 3] = 1e30 if dt_name == "float32" else 3e4
        x[2 * n // 3] = 1e-30
    return jnp.asarray(x, lay.dtype)


# ---------------------------------------------------------------------------
# fused == legacy composition, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dt", ["bfloat16", "float32", "float16"])
@pytest.mark.parametrize("width", [1, 2, 3, 5, 8])
def test_fused_jnp_matches_composition(dt, width):
    x = make_input(dt, 3 * 4096, seed=width)
    got = ops.encode_fused(x, width, use_pallas=False)
    assert_wire_equal(got, legacy_wire(x, width), (dt, width))


# ragged shapes: below a block, block-but-not-tile, tile+tail, group-ragged
RAGGED = [37, 600, 1536, 5000, TILE + 513, 2 * TILE]


@pytest.mark.parametrize("dt", ["bfloat16", "float32"])
@pytest.mark.parametrize("n", RAGGED)
def test_fused_jnp_ragged_matches_composition(dt, n):
    x = make_input(dt, n, seed=n, poison=n > 1000)
    got = ops.encode_fused(x, 5, use_pallas=False)
    assert_wire_equal(got, legacy_wire(x, 5), (dt, n))


@pytest.mark.parametrize("dt", ["bfloat16", "float32"])
@pytest.mark.parametrize("n", [TILE, 600, TILE + 513])
def test_fused_pallas_matches_composition(dt, n):
    """Interpret-mode Pallas kernel, including the ragged pad-to-tile path
    (no silent fallback: these shapes run the kernel grid)."""
    x = make_input(dt, n, seed=n)
    got = ops.encode_fused(x, 5, use_pallas=True)
    assert_wire_equal(got, legacy_wire(x, 5), (dt, n))


def test_fused_pallas_kernel_planes_match_ref():
    """Kernel vs jnp oracle at the plane level (payload/lo/bases/rng)."""
    from repro.kernels import encode_fused as ek
    x = make_input("bfloat16", TILE, seed=3)
    got = ek.encode_fused(x, 5, interpret=True)
    want = ref.encode_fused(x, 5)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype and (g == w).all()


@given(st.integers(1, 8), st.integers(1, 40))
@settings(max_examples=20, deadline=None)
def test_fused_property_random_width_and_shape(width, blocks_third):
    """Property sweep: arbitrary widths x ragged lengths stay bit-identical
    (lengths stride over group/block/tile boundaries)."""
    n = 171 * blocks_third  # strides across block boundaries
    x = make_input("bfloat16", n, seed=width * 100 + n, poison=False)
    got = ops.encode_fused(x, width, use_pallas=False)
    assert_wire_equal(got, legacy_wire(x, width), (width, n))


@pytest.mark.parametrize("width", [12, 16, 24, 30])
def test_fused_wide_width_matches_composition(width):
    """Widths past the 8-bit exponent range are wasteful but legal (extra
    all-zero planes); parity must hold up to the composition's own int32
    comparison limit (width 30)."""
    x = make_input("bfloat16", 2048, seed=width, poison=False)
    got = ops.encode_fused(x, width, use_pallas=False)
    assert_wire_equal(got, legacy_wire(x, width), width)


@pytest.mark.parametrize("width", list(range(1, 33, 3)) + [32])
def test_bitplane_pack_width_sweep_roundtrip(width):
    """pack/unpack parity+inversion for every plane count up to 32 (the
    full uint32 lane) — the fused encode emits this exact layout."""
    rng = np.random.default_rng(width)
    hi = 1 << min(width, 31)
    vals = jnp.asarray(rng.integers(0, hi, 32 * 256), jnp.uint32)
    pk = ops.pack(vals, width, use_pallas=True)
    assert (pk == ref.pack(vals, width)).all()
    assert (ops.unpack(pk, width, use_pallas=True) == vals).all()


def test_fused_all_zero_and_uniform_blocks():
    """Degenerate statistics: all-zero blocks (base escape -> 1) and
    constant blocks (rng == 1) must match the composition exactly."""
    x = jnp.zeros((2048,), jnp.bfloat16)
    assert_wire_equal(ops.encode_fused(x, 4, use_pallas=False),
                      legacy_wire(x, 4), "zeros")
    x = jnp.full((2048,), 0.5, jnp.bfloat16)
    assert_wire_equal(ops.encode_fused(x, 1, use_pallas=False),
                      legacy_wire(x, 1), "const")


def test_fused_overflow_flag_parity():
    """Exception-capacity overflow must fire identically on both paths."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(2.0 ** rng.uniform(-30, 30, 4096), jnp.bfloat16)
    got = ops.encode_fused(x, 2, exc_frac=0.01, use_pallas=False)
    want = legacy_wire(x, 2, exc_frac=0.01)
    assert int(got["overflow"]) == int(want["overflow"]) == 1
    assert_wire_equal(got, want, "overflow")


# ---------------------------------------------------------------------------
# chunked encode + round-trip through the fused receive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dt", ["bfloat16", "float32"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_encode_chunks_fused_matches_legacy(dt, use_pallas):
    lay = codec.LAYOUTS[dt]
    rng = np.random.default_rng(9)
    x = rng.normal(0, 0.02, (4, 2048))
    x[rng.random((4, 2048)) < 0.05] = 0.0
    x = jnp.asarray(x, lay.dtype)
    got = cc._encode_chunks(x, width=5, block=512, exc_frac=0.02,
                            fused=True, use_pallas=use_pallas)
    want = cc._encode_chunks(x, width=5, block=512, exc_frac=0.02,
                             fused=False)
    assert_wire_equal(got, want, (dt, use_pallas))


@pytest.mark.parametrize("dt", ["bfloat16", "float32"])
def test_fused_encode_roundtrip_through_decode_reduce(dt):
    """encode_fused wire -> fused decode+reduce == sequential f32 sum of
    the original chunks: the full fused transmit+receive loop is lossless
    (exceptions included)."""
    lay = codec.LAYOUTS[dt]
    rng = np.random.default_rng(11)
    x = rng.normal(0, 0.02, (3, 4096))
    x[rng.random((3, 4096)) < 0.05] = 0.0
    x[0, 100] = 1e30 if dt == "float32" else 3e4  # exception block
    x = jnp.asarray(x, lay.dtype)
    wire = cc._encode_chunks(x, width=4, block=512, exc_frac=0.02, fused=True)
    acc, flag = cc._decode_reduce_chunks(wire, dtype=x.dtype, n=4096,
                                         width=4, block=512)
    want = cc._seq_sum(x, jnp.float32)
    assert int(flag) == 0
    assert (jax.lax.bitcast_convert_type(acc, jnp.uint32)
            == jax.lax.bitcast_convert_type(want, jnp.uint32)).all()


def test_encode_message_fused_default_and_roundtrip():
    """packing.encode_message routes through the fused dispatch by default,
    bit-identical to the legacy composition, and decode_message inverts."""
    x = make_input("bfloat16", 3000, seed=13)
    m_fused = packing.encode_message(x, width=4)
    m_legacy = packing.encode_message(x, width=4, fused=False)
    assert (m_fused.lo == m_legacy.lo).all()
    for f in ("payload", "bases", "exc_idx", "exc_raw", "overflow"):
        assert (getattr(m_fused.exp, f) == getattr(m_legacy.exp, f)).all(), f
    y = packing.decode_message(m_fused)
    u = codec.LAYOUTS["bfloat16"].uint_dtype
    assert (jax.lax.bitcast_convert_type(y, u)
            == jax.lax.bitcast_convert_type(x, u)).all()


# ---------------------------------------------------------------------------
# probe-driven dispatch (REPRO_USE_PALLAS) and fallback accounting
# ---------------------------------------------------------------------------

def test_probe_drives_fused_encode(monkeypatch):
    """REPRO_USE_PALLAS=1: use_pallas=None routes the encode through the
    interpret-mode Pallas kernel, bit-identical to the reference."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    kernels.probe_cache_clear()
    try:
        x = make_input("bfloat16", TILE + 600, seed=17)
        got = ops.encode_fused(x, 5, use_pallas=None)  # None -> probe -> True
        assert_wire_equal(got, legacy_wire(x, 5), "probe")
    finally:
        monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
        kernels.probe_cache_clear()


def test_kernel_fallbacks_counted_and_exposed():
    """The ops fast paths count (instead of silently absorbing) every
    requested-Pallas-but-shape-gated degrade; the fused encode does NOT
    degrade on ragged shapes (pad-to-tile keeps it on the kernel)."""
    kernels.clear_fallbacks()
    try:
        vals = jnp.zeros((32 * 3,), jnp.uint32)  # not a 32*TILE_G multiple
        ops.pack(vals, 4, use_pallas=True)
        ops.unpack(jnp.zeros((3, 4), jnp.uint32), 4, use_pallas=True)
        ops.split_with_stats(jnp.zeros((1024,), jnp.bfloat16),
                             use_pallas=True)
        counts = kernels.fallback_counts()
        assert counts == {"pack": 1, "unpack": 1, "split_with_stats": 1}
        # ragged fused encode: Pallas path, NO fallback recorded
        ops.encode_fused(make_input("bfloat16", 600, poison=False), 5,
                         use_pallas=True)
        assert kernels.fallback_counts() == counts
        # misaligned chunked encode degrades VISIBLY to the composition
        cc._encode_chunks(jnp.zeros((2, 600), jnp.bfloat16), width=4,
                          block=512, exc_frac=0.02, fused=True)
        assert kernels.fallback_counts()["encode_fused_chunks"] == 1
    finally:
        kernels.clear_fallbacks()


# ---------------------------------------------------------------------------
# policy knob, wire accounting, and plan threading
# ---------------------------------------------------------------------------

def _trace_psum_reports(fused_encode):
    from benchmarks.fig_encode import trace_encode_reports
    return trace_encode_reports(8, 1 << 18, jnp.bfloat16,
                                fused_encode=fused_encode)


def test_wire_reports_carry_encode_side_accounting():
    """Every compressed send phase reports the split-plane round-trip;
    the fused_encode knob moves it between paid and eliminated."""
    from repro.roofline.analysis import summarize_wire_reports
    s_f = summarize_wire_reports(_trace_psum_reports(True))
    s_u = summarize_wire_reports(_trace_psum_reports(False))
    assert s_f["encode_hbm_eliminated"] > 0 and s_f["encode_hbm_paid"] == 0
    assert s_u["encode_hbm_paid"] == s_f["encode_hbm_eliminated"]
    assert s_u["encode_hbm_eliminated"] == 0


def test_policy_fused_encode_bit_identical_one_device():
    """fused_encode on/off produce bit-identical collectives (1-dev mesh)."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": make_input("bfloat16", 1 << 14, seed=19, poison=False),
            "b": make_input("float32", 4096, seed=20, poison=False)}
    outs = []
    for fe in (True, False):
        pol = CompressionPolicy(min_bytes=0, fused_encode=fe)
        out, flag = jax.jit(jax.shard_map(
            lambda t, _p=pol: cc.tree_psum_compressed(t, "data", policy=_p),
            mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
            axis_names={"data"}, check_vma=False))(tree)
        assert int(flag) == 0
        outs.append(out)
    for k in tree:
        u = codec.layout_of(tree[k].dtype).uint_dtype
        assert (jax.lax.bitcast_convert_type(outs[0][k], u)
                == jax.lax.bitcast_convert_type(outs[1][k], u)).all(), k


def test_plan_records_encode_fused_and_fingerprint_misses():
    """BucketPlan.encode_fused follows the policy knob; flipping the knob
    is a fingerprint change -> plan-cache miss (stale schedules never
    replay)."""
    from repro import sched
    from repro.sched import compile as sched_compile
    tree = {"w": jnp.zeros((1 << 15,), jnp.bfloat16)}
    pol = CompressionPolicy(min_bytes=0)
    plan = sched_compile.compile_psum_plan(tree, "data", policy=pol, n_dev=8)
    assert all(b.encode_fused for b in plan.buckets)
    assert plan.summary()["n_encode_fused"] == 1
    pol_off = dataclasses.replace(pol, fused_encode=False)
    plan_off = sched_compile.compile_psum_plan(tree, "data", policy=pol_off,
                                               n_dev=8)
    assert not any(b.encode_fused for b in plan_off.buckets)
    cache = sched.PlanCache()
    for p in (pol, pol_off):
        key = sched_compile.psum_plan_key(tree, "data", p, "gradient", 8)
        cache.get_or_compile(key, lambda _p=p, _k=key: (
            sched_compile.compile_psum_plan(tree, "data", policy=_p, n_dev=8,
                                            key=_k)))
    assert cache.stats.misses == 2  # knob flip cannot hit the old plan


def test_plan_executor_encode_parity_one_device():
    """psum_with_plan replays the recorded encode_fused flag bit-identically
    to the planless path, for both knob settings."""
    from jax.sharding import PartitionSpec as P
    from repro import sched
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": make_input("bfloat16", 1 << 14, seed=23, poison=False)}
    for fe in (True, False):
        pol = CompressionPolicy(min_bytes=0, fused_encode=fe)
        a, fa = jax.jit(jax.shard_map(
            lambda t, _p=pol: sched.psum_with_plan(
                t, "data", policy=_p, cache=sched.PlanCache()),
            mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
            axis_names={"data"}, check_vma=False))(tree)
        b, fb = jax.jit(jax.shard_map(
            lambda t, _p=pol: cc.tree_psum_compressed(t, "data", policy=_p),
            mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
            axis_names={"data"}, check_vma=False))(tree)
        assert int(fa) == int(fb) == 0
        assert (jax.lax.bitcast_convert_type(a["w"], jnp.uint16)
                == jax.lax.bitcast_convert_type(b["w"], jnp.uint16)).all()


def test_encode_send_fused_parity_one_device():
    """encode_send's fused encode is bit-identical to its legacy path and
    lossless through the wire (identity perm)."""
    from jax.sharding import PartitionSpec as P
    from repro.core.split_send import encode_send
    mesh = jax.make_mesh((1,), ("data",))
    x = make_input("bfloat16", 2048 + 100, seed=29, poison=False)

    def body(v):
        a, f1 = encode_send(v, "data", [(0, 0)], width=5, fused_encode=True)
        b, f2 = encode_send(v, "data", [(0, 0)], width=5, fused_encode=False)
        return a, b, jnp.maximum(f1, f2)

    a, b, flag = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    assert int(flag) == 0
    assert (jax.lax.bitcast_convert_type(a, jnp.uint16)
            == jax.lax.bitcast_convert_type(b, jnp.uint16)).all()
    assert (jax.lax.bitcast_convert_type(a, jnp.uint16)
            == jax.lax.bitcast_convert_type(x, jnp.uint16)).all()


# ---------------------------------------------------------------------------
# benchmark smoke (CI gate: must stay fast)
# ---------------------------------------------------------------------------

def test_fig_encode_smoke_runs():
    from benchmarks.fig_encode import run
    out = run(smoke=True)
    assert out["parity"] is True
    assert out["min_reduction"] >= 2.0
