"""Static wire codec: bitplane packing, exceptions, overflow semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st  # hypothesis or fallback

from repro.core import codec, packing


@pytest.mark.parametrize("width", [1, 2, 3, 4, 5, 7, 8, 11, 16, 24])
def test_bitplane_roundtrip(width):
    rng = np.random.default_rng(width)
    vals = jnp.asarray(rng.integers(0, 1 << width, 32 * 17), jnp.uint32)
    pk = packing.bitplane_pack(vals, width)
    assert pk.shape == (17, width)
    up = packing.bitplane_unpack(pk, width)
    assert (up == vals).all()


@given(st.integers(1, 8), st.integers(1, 20))
@settings(max_examples=25, deadline=None)
def test_bitplane_roundtrip_property(width, groups):
    rng = np.random.default_rng(width * 100 + groups)
    vals = jnp.asarray(rng.integers(0, 1 << width, 32 * groups), jnp.uint32)
    assert (packing.bitplane_unpack(packing.bitplane_pack(vals, width), width) == vals).all()


@pytest.mark.parametrize("dt", list(codec.LAYOUTS))
@pytest.mark.parametrize("width", [4, 8])
def test_message_roundtrip(dt, width):
    lay = codec.LAYOUTS[dt]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(-1, 1, 3000), lay.dtype)
    m = packing.encode_message(x, width=width)
    y = packing.decode_message(m)
    xb = jax.lax.bitcast_convert_type(x, lay.uint_dtype)
    yb = jax.lax.bitcast_convert_type(y, lay.uint_dtype)
    assert (xb == yb).all()
    assert int(m.exp.overflow) == 0


def test_exceptions_restore_wild_blocks():
    """Blocks with exponent range > 2^W must round-trip via the exception
    region (paper's 'tails raw', made exact)."""
    rng = np.random.default_rng(4)
    x = np.random.default_rng(4).uniform(0.5, 1.0, 4096).astype(np.float32)
    # poison two blocks with huge dynamic range
    x[100] = 1e-30
    x[1500] = 1e30
    x = jnp.asarray(x)
    m = packing.encode_message(x, width=2)
    assert int(m.exp.overflow) == 0  # capacity covers 2 blocks
    y = packing.decode_message(m)
    assert (jax.lax.bitcast_convert_type(x, jnp.uint32)
            == jax.lax.bitcast_convert_type(y, jnp.uint32)).all()


def test_overflow_flag_fires_and_never_lies():
    """If overflow==0 the decode MUST be exact; if the data is too wild for
    (W, capacity), the flag must be 1."""
    rng = np.random.default_rng(5)
    # exponents uniform over the full range -> every block escapes
    bits = rng.integers(0, 1 << 16, 8192).astype(np.uint16)
    x = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
    m = packing.encode_message(x, width=2, exc_frac=0.01)
    assert int(m.exp.overflow) == 1
    # generous capacity: exact again
    m2 = packing.encode_message(x, width=2, exc_frac=1.0)
    assert int(m2.exp.overflow) == 0
    y2 = packing.decode_message(m2)
    assert (jax.lax.bitcast_convert_type(y2, jnp.uint16) == jnp.asarray(bits)).all()


@given(
    st.integers(1, 8),
    st.lists(st.integers(0, 255), min_size=1, max_size=600),
)
@settings(max_examples=30, deadline=None)
def test_pack_exponents_property(width, exps):
    """For arbitrary exponent bytes: overflow==0 implies exact decode."""
    exp = jnp.asarray(np.asarray(exps, np.uint8))
    p = packing.pack_exponents(exp, width=width, block=64, exc_frac=0.5)
    out = packing.unpack_exponents(p)
    if int(p.overflow) == 0:
        assert (out == exp).all()


def test_wire_ratio_accounting():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.uniform(-1, 1, 1 << 18), jnp.bfloat16)
    m = packing.encode_message(x, width=4)
    # bf16 W=4: (8 + 4 + eps) / 16 ~ 0.75 + exception overhead
    assert 0.70 < m.ratio() < 0.80, m.ratio()
    m8 = packing.encode_message(x, width=8)
    assert m8.ratio() > 1.0  # W=8 == raw + overhead (no compression claimed)


def test_jit_static_shapes():
    """Wire shapes are static: the same jitted encoder serves every step."""
    enc = jax.jit(lambda v: packing.encode_message(v, width=4))
    x1 = jnp.ones((2048,), jnp.bfloat16)
    x2 = jnp.zeros((2048,), jnp.bfloat16)
    m1, m2 = enc(x1), enc(x2)
    assert m1.lo.shape == m2.lo.shape
    assert m1.exp.payload.shape == m2.exp.payload.shape
