"""Split-send P2P pipelines: non-divisible sizes and the degenerate-chunk
guard (regression for all-padding chunks when n < chunks * block).

A 1-device mesh with the identity perm exercises the full encode/wire/
decode path of every strategy; 8-device exactness lives in test_multidev.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import codec
from repro.core.split_send import (chunked_pipeline_send, encode_send,
                                   split_send)

STRATEGIES = [("split", split_send), ("encode", encode_send),
              ("chunked", chunked_pipeline_send)]
# non-divisible sizes: < block, < chunks*block, block-straddling, and a
# size whose ceil(n/chunks) block-rounding used to leave an empty chunk
SIZES = [100, 513, 1537, 2048, 512 * 4 + 17]


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1,), ("data",))


@pytest.mark.parametrize("name,fn", STRATEGIES)
@pytest.mark.parametrize("n", SIZES)
def test_non_divisible_sizes_exact(mesh, name, fn, n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(0, 0.02, n), jnp.bfloat16)

    def body(v):
        got, flag = fn(v, "data", [(0, 0)], width=5)
        return got, flag

    got, flag = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    assert int(flag) == 0
    u = codec.layout_of(x.dtype).uint_dtype
    assert (jax.lax.bitcast_convert_type(got, u)
            == jax.lax.bitcast_convert_type(x, u)).all(), (name, n)


@pytest.mark.parametrize("n,chunks", [(100, 4), (2048, 3), (1537, 4)])
def test_chunked_no_padding_only_chunks(mesh, n, chunks):
    """Every pipelined chunk must carry real data — the effective chunk
    count shrinks instead of encoding/sending all-padding rows."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(0, 0.02, n), jnp.bfloat16)

    def body(v):
        got, flag = chunked_pipeline_send(v, "data", [(0, 0)], width=5,
                                          chunks=chunks)
        return got, flag

    got, flag = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    u = codec.layout_of(x.dtype).uint_dtype
    assert (jax.lax.bitcast_convert_type(got, u)
            == jax.lax.bitcast_convert_type(x, u)).all()


def test_chunked_rejects_empty():
    with pytest.raises(ValueError):
        chunked_pipeline_send(jnp.zeros((0,), jnp.bfloat16), "data",
                              [(0, 0)], width=5)
