"""Split-send P2P pipelines: non-divisible sizes and the degenerate-chunk
guard (regression for all-padding chunks when n < chunks * block).

A 1-device mesh with the identity perm exercises the full encode/wire/
decode path of every strategy; 8-device exactness lives in test_multidev.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import codec
from repro.core.split_send import (chunked_pipeline_send, encode_send,
                                   p2p_send, split_send)

STRATEGIES = [("split", split_send), ("encode", encode_send),
              ("chunked", chunked_pipeline_send)]
# non-divisible sizes: < block, < chunks*block, block-straddling, and a
# size whose ceil(n/chunks) block-rounding used to leave an empty chunk
SIZES = [100, 513, 1537, 2048, 512 * 4 + 17]


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1,), ("data",))


@pytest.mark.parametrize("name,fn", STRATEGIES)
@pytest.mark.parametrize("n", SIZES)
def test_non_divisible_sizes_exact(mesh, name, fn, n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(0, 0.02, n), jnp.bfloat16)

    def body(v):
        got, flag = fn(v, "data", [(0, 0)], width=5)
        return got, flag

    got, flag = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    assert int(flag) == 0
    u = codec.layout_of(x.dtype).uint_dtype
    assert (jax.lax.bitcast_convert_type(got, u)
            == jax.lax.bitcast_convert_type(x, u)).all(), (name, n)


@pytest.mark.parametrize("n,chunks", [(100, 4), (2048, 3), (1537, 4)])
def test_chunked_no_padding_only_chunks(mesh, n, chunks):
    """Every pipelined chunk must carry real data — the effective chunk
    count shrinks instead of encoding/sending all-padding rows."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(0, 0.02, n), jnp.bfloat16)

    def body(v):
        got, flag = chunked_pipeline_send(v, "data", [(0, 0)], width=5,
                                          chunks=chunks)
        return got, flag

    got, flag = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    u = codec.layout_of(x.dtype).uint_dtype
    assert (jax.lax.bitcast_convert_type(got, u)
            == jax.lax.bitcast_convert_type(x, u)).all()


def test_chunked_rejects_empty():
    with pytest.raises(ValueError):
        chunked_pipeline_send(jnp.zeros((0,), jnp.bfloat16), "data",
                              [(0, 0)], width=5)


# -- fused reducing receiver (ROADMAP: split_send -> _decode_reduce_chunks) --

def bits32(a):
    return jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.uint32)


@pytest.mark.parametrize("dt", ["bfloat16", "float32"])
@pytest.mark.parametrize("n", [100, 2048, 512 * 4 + 17])
def test_split_send_reduce_into_fused_parity(mesh, dt, n):
    """Reducing receiver: the fused decode+reduce receive must be
    bit-identical to decode-then-add (and to acc + x, since the wire is
    lossless and the perm is the identity)."""
    rng = np.random.default_rng(n)
    lay = codec.LAYOUTS[dt]
    x = jnp.asarray(rng.normal(0, 0.02, n), lay.dtype)
    acc = jnp.asarray(rng.normal(0, 1, n), jnp.float32)

    def body(v, a):
        fused, f1 = split_send(v, "data", [(0, 0)], width=5, reduce_into=a,
                               use_fused=True)
        unfused, f2 = split_send(v, "data", [(0, 0)], width=5, reduce_into=a,
                                 use_fused=False)
        return fused, unfused, jnp.maximum(f1, f2)

    fused, unfused, flag = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P(), P()),
        axis_names={"data"}, check_vma=False))(x, acc)
    assert int(flag) == 0
    assert (bits32(fused) == bits32(unfused)).all()
    assert (bits32(fused) == bits32(acc + x.astype(jnp.float32))).all()


def test_split_send_reduce_into_exception_blocks(mesh):
    """Poison values ride the exception region; the fused receiver's exact
    patch-up must keep parity with decode-then-add bit-for-bit."""
    rng = np.random.default_rng(7)
    x = np.asarray(rng.normal(0, 0.02, 4096))
    x[100], x[700], x[2049] = 1e30, 1e-30, -1e30
    x = jnp.asarray(x, jnp.bfloat16)
    acc = jnp.asarray(rng.normal(0, 1, 4096), jnp.float32)

    def body(v, a):
        fused, f1 = split_send(v, "data", [(0, 0)], width=4, reduce_into=a,
                               use_fused=True)
        unfused, f2 = split_send(v, "data", [(0, 0)], width=4, reduce_into=a,
                                 use_fused=False)
        return fused, unfused, jnp.maximum(f1, f2)

    fused, unfused, flag = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P(), P()),
        axis_names={"data"}, check_vma=False))(x, acc)
    assert int(flag) == 0
    assert (bits32(fused) == bits32(unfused)).all()


def test_p2p_reducing_receiver_hbm_accounting():
    """A reducing receiver's WireReports must carry the decoded-float HBM
    round-trip: ELIMINATED for the fused split_send path, PAID for the
    decode-then-add strategies — comparable across strategies."""
    import jax.numpy as jnp
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.core import policy as policy_lib
    from repro.core.policy import CompressionPolicy

    try:
        am = AbstractMesh((("data", 8),))
    except TypeError:
        am = AbstractMesh((8,), ("data",))
    pol = CompressionPolicy(min_bytes=0)
    perm = [(i, (i + 1) % 8) for i in range(8)]
    x = jax.ShapeDtypeStruct((1 << 14,), jnp.bfloat16)

    def reports_for(strategy):
        policy_lib.clear_wire_reports()
        jax.eval_shape(jax.shard_map(
            lambda v, a: p2p_send(v, "data", perm, policy=pol,
                                  strategy=strategy, reduce_into=a),
            mesh=am, in_specs=(P(), P()), out_specs=(P(), P()),
            axis_names={"data"}, check_vma=False),
            x, jax.ShapeDtypeStruct((1 << 14,), jnp.float32))
        reps = policy_lib.wire_reports()
        policy_lib.clear_wire_reports()
        return reps

    fused = reports_for("split_send")
    assert all(r.fused and r.decode_hbm_bytes > 0 for r in fused)
    unfused = reports_for("encode_send")
    assert all(not r.fused and r.decode_hbm_bytes > 0 for r in unfused)
    assert (sum(r.decode_hbm_bytes for r in unfused)
            == sum(r.decode_hbm_bytes for r in fused))


def test_p2p_send_reduce_into_all_strategies(mesh):
    """p2p_send threads the reducing receiver through every strategy and
    the raw fallback with identical (bit-exact) results."""
    from repro.core.policy import CompressionPolicy
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(0, 0.02, 2048), jnp.bfloat16)
    acc = jnp.asarray(rng.normal(0, 1, 2048), jnp.float32)
    want = acc + x.astype(jnp.float32)
    pols = [CompressionPolicy(min_bytes=0), CompressionPolicy.disabled()]
    for pol in pols:
        for strat in ("split_send", "encode_send", "chunked"):
            def body(v, a, _p=pol, _s=strat):
                return p2p_send(v, "data", [(0, 0)], policy=_p, strategy=_s,
                                reduce_into=a)

            got, flag = jax.jit(jax.shard_map(
                body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                axis_names={"data"}, check_vma=False))(x, acc)
            assert int(flag) == 0
            assert (bits32(got) == bits32(want)).all(), (strat, pol.enabled)
