"""Pallas kernels vs pure-jnp oracles: shape/dtype/width sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ans as core_ans
from repro.core import codec, packing
from repro.kernels import ops, ref
from repro.kernels.bitpack import TILE_G
from repro.kernels.plane_split import TILE_B


@pytest.mark.parametrize("width", [1, 2, 4, 5, 8, 11, 24])
@pytest.mark.parametrize("tiles", [1, 3])
def test_bitpack_kernel_matches_ref(width, tiles):
    rng = np.random.default_rng(width)
    n = 32 * TILE_G * tiles
    vals = jnp.asarray(rng.integers(0, 1 << width, n), jnp.uint32)
    assert (ops.pack(vals, width, use_pallas=True) == ref.pack(vals, width)).all()
    pk = ref.pack(vals, width)
    assert (ops.unpack(pk, width, use_pallas=True) == vals).all()


@pytest.mark.parametrize("dt", list(codec.LAYOUTS))
@pytest.mark.parametrize("tiles", [1, 2])
def test_plane_split_kernel_matches_ref(dt, tiles):
    lay = codec.LAYOUTS[dt]
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 2, 512 * TILE_B * tiles), lay.dtype)
    got = ops.split_with_stats(x, use_pallas=True)
    want = ref.split_with_stats(x)
    for g, w in zip(got, want):
        assert (g == w).all()


@pytest.mark.parametrize("dt", ["bfloat16", "float32"])
@pytest.mark.parametrize("width", [3, 5, 8])
def test_decode_reduce_kernel_matches_ref(dt, width):
    """Kernel vs jnp oracle on the REAL wire format (pack_exponents
    zero-escape; exception blocks carry clamped payload — the kernel and
    oracle must agree on those too, the collective patches them after)."""
    lay = codec.LAYOUTS[dt]
    rng = np.random.default_rng(8)
    n = 32 * TILE_G
    x = np.asarray(rng.normal(0, 1, n))
    x[rng.random(n) < 0.05] = 0.0  # exact zeros: exercise the escape
    x = jnp.asarray(x, lay.dtype)
    exp, lo = codec.split_planes(x)
    pk = packing.pack_exponents(exp, width=width, block=512)
    gb = jnp.repeat(pk.bases.astype(jnp.uint32), 512 // 32)
    lo_planes = packing.bitplane_pack(lo.astype(jnp.uint32), lay.lo_bits)
    acc = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = ops.decode_reduce(pk.payload, lo_planes, gb, acc, dt, width,
                            use_pallas=True)
    want = ref.decode_reduce(pk.payload, lo_planes, gb, acc, dt, width)
    assert (got == want).all()
    if width == 8:  # no exception blocks possible: exact vs unfused decode
        full = codec.merge_planes(packing.unpack_exponents(pk),
                                  lo.astype(lay.uint_dtype), lay.dtype, (n,))
        assert (got == acc + full.astype(jnp.float32)).all()


@pytest.mark.parametrize("per", [1, 8, 64])
@pytest.mark.parametrize("lanes", [128, 256])
def test_rans_kernel_matches_ref_and_inverts(per, lanes):
    rng = np.random.default_rng(per * 1000 + lanes)
    syms = jnp.asarray(
        np.clip(rng.normal(120, 4, (per, lanes)), 0, 255).astype(np.uint32)
    )
    table = core_ans.build_freq_table(syms.astype(jnp.uint8).reshape(-1))
    wk, mk, sk = ops.rans_encode(syms, table, use_pallas=True)
    wr, mr, sr = ref.rans_encode(syms, table.freq, table.cum[:256])
    assert (wk == wr).all() and (mk == mr).all() and (sk == sr).all()
    out = ops.rans_decode(wk, sk, table, use_pallas=True)
    assert (out == syms).all()


def test_rans_kernel_adversarial_uniform():
    """Incompressible symbols: kernel must stay exact (just emits ~every row)."""
    rng = np.random.default_rng(99)
    syms = jnp.asarray(rng.integers(0, 256, (32, 128)).astype(np.uint32))
    table = core_ans.build_freq_table(syms.astype(jnp.uint8).reshape(-1))
    w, m, s = ops.rans_encode(syms, table, use_pallas=True)
    assert (ops.rans_decode(w, s, table, use_pallas=True) == syms).all()
    # uniform-256 data costs ~8 bits/sym ~= 0.5 words/sym (state absorbs a bit)
    assert float(m.mean()) > 0.4
