"""Multi-device correctness driver (run in a subprocess: the XLA host-device
flag must be set before jax init, and the main pytest process must keep the
default 1-device view per the assignment).

Each section runs independently (a lowering failure in one records an error
for its keys instead of killing the rest).  Prints one JSON line with all
results; a value of the form {"skip": reason} marks a check the installed
jax/jaxlib cannot lower (the suite skips instead of failing).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import json
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core.compressed_collectives import (
    all_to_all_compressed, psum_compressed, psum_raw_twoshot,
    reduce_scatter_compressed, tree_psum_compressed)
from repro.core.policy import CompressionPolicy
from repro.core.split_send import (chunked_pipeline_send, encode_send,
                                   p2p_send, split_send)
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.optim import optimizers as opt_lib
from repro.serve.kv_transfer import transfer_cache
from repro.train.step import TrainConfig, build_train_state, build_train_step

res = {}
# model axis kept trivial (=1): nested shard_map with auto axes inside the
# rematted forward scan cannot lower on jaxlib 0.4.x for model>1 (verified
# fine on current jax); dp spans 8 devices, which is what the compressed
# collectives under test ride on.
mesh3 = make_mesh((2, 4, 1), ("pod", "data", "model"))
mesh1 = make_mesh((8,), ("data",))
policy = CompressionPolicy(min_bytes=0)
rng = np.random.default_rng(0)


def section(name, keys):
    """Decorator: run a section, mapping exceptions to per-key skip records."""
    def deco(fn):
        try:
            fn()
        except Exception as e:  # record per-key skip, keep other sections
            first = str(e).splitlines()[0][:200] if str(e) else ""
            err = f"{type(e).__name__}: {first}"
            for k in keys:
                res.setdefault(k, {"skip": err})
            print(f"SECTION {name} failed: {err}", file=sys.stderr)
            traceback.print_exc(limit=2, file=sys.stderr)
    return deco


def bits_equal(a, b):
    if a.dtype in (jnp.bfloat16, jnp.float16):
        u = jnp.uint16
        return bool(jnp.all(jax.lax.bitcast_convert_type(a, u)
                            == jax.lax.bitcast_convert_type(b, u)))
    return bool(jnp.all(a == b))


# -- 1. psum_compressed == raw psum (both algorithms) -------------------------
# two-shot: ONE f32 reduction -> bit-equal to the f32 reference.
# ring: every hop re-encodes the partial sum in the wire dtype (bf16), so
# intermediate sums round — numerically close but NOT bit-equal.  This is
# the re-compression overhead the paper ascribes to ring (Fig. 9b).
@section("psum", ["psum_two_shot_exact", "psum_two_shot_flag",
                  "psum_ring_exact", "psum_ring_flag"])
def _psum():
    x = jnp.asarray(rng.normal(0, 0.02, (1 << 16,)), jnp.bfloat16)
    for algo in ["two_shot", "ring"]:
        pol = dataclasses.replace(policy, allreduce_algorithm=algo)

        def f(v):
            out, flag = psum_compressed(v, "data", policy=pol)
            return out, flag

        out, flag = jax.jit(jax.shard_map(
            f, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P()),
            axis_names={"data"}, check_vma=False))(x)
        ref = (x.astype(jnp.float32) * 8).astype(jnp.bfloat16)
        if algo == "two_shot":
            res[f"psum_{algo}_exact"] = bits_equal(out, ref)
        else:
            rel = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                        - ref.astype(jnp.float32)))) / \
                float(jnp.max(jnp.abs(ref.astype(jnp.float32))))
            res[f"psum_{algo}_exact"] = rel < 5e-2  # bf16 per-hop rounding
        res[f"psum_{algo}_flag"] = int(flag)


# -- 1b. fused vs unfused reduce-scatter: bit-identical across 8 devices ------
@section("rs_fused", ["rs_fused_bitexact_bfloat16", "rs_fused_bitexact_float32"])
def _rs_fused():
    for dt in [jnp.bfloat16, jnp.float32]:
        x = jnp.asarray(rng.normal(0, 0.02, (1 << 15,)), dt)

        def f(v):
            a, fa = reduce_scatter_compressed(v, "data", width=5,
                                              use_fused=True)
            b, fb = reduce_scatter_compressed(v, "data", width=5,
                                              use_fused=False)
            return a, b, jnp.maximum(fa, fb)

        a, b, flag = jax.jit(jax.shard_map(
            f, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P(), P()),
            axis_names={"data"}, check_vma=False))(x)
        name = jnp.dtype(dt).name
        res[f"rs_fused_bitexact_{name}"] = (
            bits_equal(a, b) and int(flag) == 0)


# -- 2. all_to_all_compressed == raw all_to_all --------------------------------
@section("a2a", ["a2a_exact", "a2a_flag"])
def _a2a():
    a = jnp.asarray(rng.normal(0, 1, (8, 4096)), jnp.bfloat16)

    def a2a_pair(v):
        vl = v.reshape(8, -1)  # local rows: one destination per device
        got, flag = all_to_all_compressed(vl, "data", policy=policy)
        want = jax.lax.all_to_all(vl.astype(jnp.float32), "data", 0, 0,
                                  tiled=False).astype(vl.dtype)
        return got.reshape(v.shape), want.reshape(v.shape), flag

    g, w, flag = jax.jit(jax.shard_map(
        a2a_pair, mesh=mesh1, in_specs=(P("data", None),),
        out_specs=(P("data", None),) * 2 + (P(),),
        axis_names={"data"}, check_vma=False))(a)
    res["a2a_exact"] = bits_equal(g, w)
    res["a2a_flag"] = int(flag)


# -- 3. split_send / encode_send / chunked == raw ppermute ---------------------
perm = [(i, (i + 1) % 8) for i in range(8)]


@section("p2p", [f"p2p_{s}_{k}" for s in ("split", "encode", "chunked")
                 for k in ("exact", "flag")])
def _p2p():
    t = jnp.asarray(rng.normal(0, 0.02, (1 << 15,)), jnp.bfloat16)
    for name, fn in [("split", split_send), ("encode", encode_send),
                     ("chunked", chunked_pipeline_send)]:
        def f(v, _fn=fn):
            got, flag = _fn(v, "data", perm, width=5)
            want = jax.lax.ppermute(v, "data", perm)
            return got, want, flag

        g, w, flag = jax.jit(jax.shard_map(
            f, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P(), P()),
            axis_names={"data"}, check_vma=False))(t)
        res[f"p2p_{name}_exact"] = bits_equal(g, w)
        res[f"p2p_{name}_flag"] = int(flag)


# -- 4. tree_psum_compressed on a mixed pytree ---------------------------------
# bf16-first tree with an f32 leaf: per-dtype bucketing must keep the f32
# leaf bit-exact at f32 precision (casting it into a bf16 bucket was the
# old lossy bug).  The reference is the DEVICE-ORDER sequential f32 sum —
# the collectives' accumulation order — not `x * 8`: sequential partial
# sums of identical f32 values legitimately round (3v, 5v, 7v need more
# mantissa bits), and losslessness means "no error beyond the uncompressed
# reduction in the same order".
@section("tree_psum", ["tree_psum_exact", "tree_psum_f32_exact"])
def _tree():
    tree = {"w": jnp.asarray(rng.normal(0, 0.02, (256, 64)), jnp.bfloat16),
            "b": jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32),
            "n": jnp.asarray(rng.integers(0, 5, (3,)), jnp.int32)}

    def tf(tr):
        out, flag = tree_psum_compressed(tr, "data", policy=policy)
        return out, flag

    out, flag = jax.jit(jax.shard_map(
        tf, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(tree)

    def seq_ref(leaf):
        acc = jnp.zeros(leaf.shape, jnp.float32)
        for _ in range(8):
            acc = acc + leaf.astype(jnp.float32)
        return acc

    ok_w = bits_equal(out["w"], seq_ref(tree["w"]).astype(jnp.bfloat16))
    ok_b = bool(jnp.all(out["b"] == seq_ref(tree["b"])))  # exact f32 bits
    ok_n = bool(jnp.all(out["n"] == tree["n"] * 8))
    res["tree_psum_exact"] = ok_w and ok_b and ok_n
    res["tree_psum_f32_exact"] = ok_b


# -- 4b. sched executor: psum_with_plan == tree_psum_compressed on 8 devs ------
@section("sched", ["sched_psum_exact", "sched_cache_hit",
                   "sched_rs_exact"])
def _sched():
    from repro import sched
    from repro.core.compressed_collectives import reduce_scatter_compressed

    tree = {"w": jnp.asarray(rng.normal(0, 0.02, (256, 64)), jnp.bfloat16),
            "b": jnp.asarray(rng.normal(0, 1, (4096,)), jnp.float32),
            "n": jnp.asarray(rng.integers(0, 5, (3,)), jnp.int32)}
    cache = sched.PlanCache()

    def planned(tr):
        return sched.psum_with_plan(tr, "data", policy=policy, cache=cache)

    def planless(tr):
        return tree_psum_compressed(tr, "data", policy=policy)

    sm = lambda f: jax.jit(jax.shard_map(
        f, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))
    a, fa = sm(planned)(tree)
    b, fb = sm(planless)(tree)
    ok = all(bits_equal(x, y) if x.dtype != jnp.int32 else
             bool(jnp.all(x == y))
             for x, y in zip(jax.tree_util.tree_leaves(a),
                             jax.tree_util.tree_leaves(b)))
    res["sched_psum_exact"] = ok and int(fa) == int(fb) == 0
    sm(planned)(tree)  # same signature: second trace must hit the cache
    res["sched_cache_hit"] = (cache.stats.hits >= 1
                              and cache.stats.misses == 1)

    x = jnp.asarray(rng.normal(0, 0.02, (1 << 15,)), jnp.bfloat16)
    a2, f2 = jax.jit(jax.shard_map(
        lambda v: sched.reduce_scatter_with_plan(
            v, "data", policy=policy, cache=sched.PlanCache()),
        mesh=mesh1, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    b2, g2 = jax.jit(jax.shard_map(
        lambda v: reduce_scatter_compressed(
            v, "data", width=policy.width_for("gradient"),
            block=policy.profile.block, exc_frac=policy.profile.exc_frac,
            use_fused=policy.fused_decode_reduce),
        mesh=mesh1, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    res["sched_rs_exact"] = (bool(jnp.all(
        jax.lax.bitcast_convert_type(a2, jnp.uint32)
        == jax.lax.bitcast_convert_type(b2, jnp.uint32)))
        and int(f2) == int(g2))


# -- 4b2. fused transmit-side encode: knob + plan parity across 8 devices ------
@section("enc_fused", ["enc_fused_bitexact", "enc_fused_plan_exact",
                       "enc_fused_plan_recorded"])
def _enc_fused():
    from repro import sched
    tree = {"w": jnp.asarray(rng.normal(0, 0.02, (1 << 15,)), jnp.bfloat16),
            "b": jnp.asarray(rng.normal(0, 1, (4096,)), jnp.float32)}
    pol_f = policy  # fused_encode=True default
    pol_u = dataclasses.replace(policy, fused_encode=False)
    sm = lambda f: jax.jit(jax.shard_map(
        f, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))
    a, fa = sm(lambda t: tree_psum_compressed(t, "data", policy=pol_f))(tree)
    b, fb = sm(lambda t: tree_psum_compressed(t, "data", policy=pol_u))(tree)
    res["enc_fused_bitexact"] = (
        all(bits_equal(a[k], b[k]) for k in tree)
        and int(fa) == int(fb) == 0)
    cache = sched.PlanCache()
    c, fc = sm(lambda t: sched.psum_with_plan(t, "data", policy=pol_f,
                                              cache=cache))(tree)
    res["enc_fused_plan_exact"] = (
        all(bits_equal(a[k], c[k]) for k in tree) and int(fc) == 0)
    plan = next(iter(cache._plans.values()))
    res["enc_fused_plan_recorded"] = all(
        bk.encode_fused for bk in plan.buckets)


# -- 4b3. p2p/kv plan executors: bit-parity + cache reuse across 8 devices -----
@section("p2p_plan", ["p2p_plan_bitexact", "p2p_plan_reduce_exact",
                      "p2p_plan_cache_hit", "kv_plan_bitexact"])
def _p2p_plan():
    from repro import sched

    t = jnp.asarray(rng.normal(0, 0.02, (1 << 15,)), jnp.bfloat16)
    acc0 = jnp.asarray(rng.normal(0, 1, (1 << 15,)), jnp.float32)
    cache = sched.PlanCache()

    def f(v, a):
        planned, f1 = sched.p2p_send_with_plan(v, "data", perm, policy=policy,
                                               cache=cache)
        planless, f2 = p2p_send(v, "data", perm, policy=policy)
        pr, f3 = sched.p2p_send_with_plan(v, "data", perm, policy=policy,
                                          reduce_into=a, cache=cache)
        ur, f4 = p2p_send(v, "data", perm, policy=policy, reduce_into=a)
        return planned, planless, pr, ur, jnp.maximum(jnp.maximum(f1, f2),
                                                      jnp.maximum(f3, f4))

    mk = lambda: jax.jit(jax.shard_map(
        f, mesh=mesh1, in_specs=(P(), P()), out_specs=(P(),) * 5,
        axis_names={"data"}, check_vma=False))
    planned, planless, pr, ur, flag = mk()(t, acc0)
    res["p2p_plan_bitexact"] = bits_equal(planned, planless) and int(flag) == 0
    res["p2p_plan_reduce_exact"] = bool(jnp.all(
        jax.lax.bitcast_convert_type(pr, jnp.uint32)
        == jax.lax.bitcast_convert_type(ur, jnp.uint32)))
    mk()(t, acc0)  # fresh jit wrapper: re-trace -> pure plan-cache hits
    # send and reducing send share one signature (reduce_into is a runtime
    # argument, not a schedule decision): 1 compile, everything else hits
    res["p2p_plan_cache_hit"] = (cache.stats.misses == 1
                                 and cache.stats.hits >= 3)

    from repro.models import transformer
    kcfg = configs.get_smoke("smollm_135m")
    kv_cache = transformer.init_cache(kcfg, 2, 64)
    params2 = transformer.init(jax.random.PRNGKey(0), kcfg)
    _, kv_cache = transformer.prefill(
        params2, registry.make_batch(kcfg, 2, 32), kcfg, kv_cache)

    def kvf(c):
        a, f1 = sched.transfer_cache_with_plan(c, "data", perm, policy=policy,
                                               plan_cache=sched.PlanCache())
        b, f2 = transfer_cache(c, "data", perm, policy=policy)
        return a, b, jnp.maximum(f1, f2)

    got, want, flag = jax.jit(jax.shard_map(
        kvf, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P(), P()),
        axis_names={"data"}, check_vma=False))(kv_cache)
    res["kv_plan_bitexact"] = all(
        bits_equal(a, b) for a, b in zip(jax.tree_util.tree_leaves(got),
                                         jax.tree_util.tree_leaves(want)))


# -- 4c. split_send fused reducing receiver across 8 devices -------------------
@section("p2p_reduce", ["p2p_reduce_into_exact"])
def _p2p_reduce():
    t = jnp.asarray(rng.normal(0, 0.02, (1 << 14,)), jnp.bfloat16)
    acc0 = jnp.asarray(rng.normal(0, 1, (1 << 14,)), jnp.float32)

    def f(v, a):
        fused, f1 = split_send(v, "data", perm, width=5, reduce_into=a,
                               use_fused=True)
        unfused, f2 = split_send(v, "data", perm, width=5, reduce_into=a,
                                 use_fused=False)
        want = a + jax.lax.ppermute(v, "data", perm).astype(jnp.float32)
        return fused, unfused, want, jnp.maximum(f1, f2)

    fused, unfused, want, flag = jax.jit(jax.shard_map(
        f, mesh=mesh1, in_specs=(P(), P()), out_specs=(P(),) * 4,
        axis_names={"data"}, check_vma=False))(t, acc0)
    res["p2p_reduce_into_exact"] = (
        bool(jnp.all(jax.lax.bitcast_convert_type(fused, jnp.uint32)
                     == jax.lax.bitcast_convert_type(unfused, jnp.uint32)))
        and bool(jnp.all(jax.lax.bitcast_convert_type(fused, jnp.uint32)
                         == jax.lax.bitcast_convert_type(want, jnp.uint32)))
        and int(flag) == 0)


# -- 5. train-step losslessness on the 3-axis mesh (zero1 + fsdp) --------------
cfg = configs.get_smoke("smollm_135m")


def _train_part(part, extra):
    batch = registry.make_batch(cfg, 16, 32)
    batch = {k: jax.device_put(v, NamedSharding(mesh3, P(("pod", "data"),
                                                        None)))
             for k, v in batch.items()}
    tc = TrainConfig(microbatches=2, policy=CompressionPolicy(min_bytes=0),
                     optim=opt_lib.OptimConfig(lr=1e-3, warmup_steps=2),
                     partition=part, **extra)
    tr = dataclasses.replace(tc, policy=CompressionPolicy.disabled())
    s1, _ = build_train_state(cfg, tc, mesh3, jax.random.PRNGKey(1))
    s2, _ = build_train_state(cfg, tr, mesh3, jax.random.PRNGKey(1))
    f1, _ = build_train_step(cfg, tc, mesh3)
    f2, _ = build_train_step(cfg, tr, mesh3)
    j1, j2 = jax.jit(f1), jax.jit(f2)
    for _ in range(2):
        s1, m1 = j1(s1, batch)
        s2, m2 = j2(s2, batch)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        s1["params"], s2["params"])
    res[f"train_{part}_bitexact"] = max(
        jax.tree_util.tree_leaves(diffs)) == 0.0
    res[f"train_{part}_loss_drop"] = float(m2["loss"]) < 6.0


@section("train_zero1", ["train_zero1_bitexact", "train_zero1_loss_drop"])
def _train_zero1():
    _train_part("zero1", {})


@section("train_fsdp", ["train_fsdp_bitexact", "train_fsdp_loss_drop"])
def _train_fsdp():
    # jaxlib 0.4.x cannot lower the per-layer compressed gathers inside the
    # rematted forward scan (verifier error); the section decorator records
    # a skip there, and the suite skips rather than fails.
    _train_part("fsdp", {"fsdp_min_bytes": 0})


# -- 6. KV-cache transfer over a mesh axis --------------------------------------
@section("kv", ["kv_transfer_exact"])
def _kv():
    from repro.models import transformer
    cache = transformer.init_cache(cfg, 2, 64)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    _, cache2 = transformer.prefill(
        params, registry.make_batch(cfg, 2, 32), cfg, cache)

    def kv(c):
        got, flag = transfer_cache(c, "data", perm, policy=policy)

        def raw(l):
            if l.ndim == 0:
                return jax.lax.ppermute(l[None], "data", perm)[0]
            return jax.lax.ppermute(l, "data", perm)

        want = jax.tree.map(raw, c)
        return got, want, flag

    got, want, flag = jax.jit(jax.shard_map(
        kv, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P(), P()),
        axis_names={"data"}, check_vma=False))(cache2)
    res["kv_transfer_exact"] = all(
        bits_equal(a, b) for a, b in zip(jax.tree_util.tree_leaves(got),
                                         jax.tree_util.tree_leaves(want)))


# -- 7. weight sync: XOR-delta broadcast + wsync plan parity across 8 devices --
@section("wsync", ["wsync_full_bitexact", "wsync_delta_bitexact",
                   "wsync_plan_parity", "wsync_plan_cache_hit"])
def _wsync():
    from repro import sched
    from repro.sync import sync_weights

    tree = {
        "wq": jnp.asarray(rng.normal(0, 0.02, (1 << 14,)), jnp.bfloat16),
        "wk": jnp.asarray(rng.normal(0, 0.02, (1 << 13,)), jnp.bfloat16),
        "norm": jnp.asarray(rng.normal(0, 1, (4096,)), jnp.float32),
        "step": jnp.asarray(3, jnp.int32),
    }
    # next version: sparse low-mantissa-bit XOR (a warm optimizer step)
    def xor_mask(l, bits_n):
        if jnp.dtype(l.dtype).name not in ("bfloat16", "float32"):
            return l
        u = jnp.uint16 if l.dtype == jnp.bfloat16 else jnp.uint32
        mask = rng.integers(0, 1 << bits_n, l.shape).astype(np.uint64)
        mask[rng.random(l.shape) > 0.3] = 0
        return jax.lax.bitcast_convert_type(
            jax.lax.bitcast_convert_type(l, u) ^ jnp.asarray(mask, u),
            l.dtype)

    new = {k: xor_mask(v, 3) for k, v in tree.items()}
    cache = sched.PlanCache()

    def f(t, b):
        full, f1 = sync_weights(t, "data", perm, policy=policy)
        delta, f2 = sync_weights(t, "data", perm, policy=policy, base=b)
        planned, f3 = sched.sync_weights_with_plan(
            t, "data", perm, policy=policy, base=b, cache=cache)
        pfull, f4 = sched.sync_weights_with_plan(
            t, "data", perm, policy=policy, cache=cache)
        flag = jnp.maximum(jnp.maximum(f1, f2), jnp.maximum(f3, f4))
        return full, delta, planned, pfull, flag

    mk = lambda: jax.jit(jax.shard_map(
        f, mesh=mesh1, in_specs=(P(), P()),
        out_specs=(P(), P(), P(), P(), P()),
        axis_names={"data"}, check_vma=False))
    full, delta, planned, pfull, flag = mk()(new, tree)
    teq = lambda a, b: all(
        bits_equal(x, y) for x, y in zip(jax.tree_util.tree_leaves(a),
                                         jax.tree_util.tree_leaves(b)))
    res["wsync_full_bitexact"] = teq(full, new) and int(flag) == 0
    res["wsync_delta_bitexact"] = teq(delta, new)
    res["wsync_plan_parity"] = teq(planned, delta) and teq(pfull, full)
    mk()(new, tree)  # fresh jit wrapper: re-trace -> pure plan-cache hits
    # delta and full replay ONE plan (delta-vs-full is runtime routing)
    res["wsync_plan_cache_hit"] = (cache.stats.misses == 1
                                   and cache.stats.hits >= 3)


print("RESULT " + json.dumps(res))
