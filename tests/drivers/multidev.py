"""Multi-device correctness driver (run in a subprocess: the XLA host-device
flag must be set before jax init, and the main pytest process must keep the
default 1-device view per the assignment).

Prints one JSON line with all results."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core.compressed_collectives import (
    all_to_all_compressed, psum_compressed, psum_raw_twoshot,
    tree_psum_compressed)
from repro.core.policy import CompressionPolicy
from repro.core.split_send import (chunked_pipeline_send, encode_send,
                                   p2p_send, split_send)
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.optim import optimizers as opt_lib
from repro.serve.kv_transfer import transfer_cache
from repro.train.step import TrainConfig, build_train_state, build_train_step

res = {}
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
mesh1 = make_mesh((8,), ("data",))
policy = CompressionPolicy(min_bytes=0)
rng = np.random.default_rng(0)


def bits_equal(a, b):
    if a.dtype == jnp.bfloat16:
        return bool(jnp.all(jax.lax.bitcast_convert_type(a, jnp.uint16)
                            == jax.lax.bitcast_convert_type(b, jnp.uint16)))
    return bool(jnp.all(a == b))


# -- 1. psum_compressed == raw psum (both algorithms) -------------------------
# two-shot: ONE f32 reduction -> bit-equal to the f32 reference.
# ring: every hop re-encodes the partial sum in the wire dtype (bf16), so
# intermediate sums round — numerically close but NOT bit-equal.  This is
# the re-compression overhead the paper ascribes to ring (Fig. 9b).
x = jnp.asarray(rng.normal(0, 0.02, (1 << 16,)), jnp.bfloat16)
for algo in ["two_shot", "ring"]:
    pol = dataclasses.replace(policy, allreduce_algorithm=algo)

    def f(v):
        out, flag = psum_compressed(v, "data", policy=pol)
        return out, flag

    out, flag = jax.jit(jax.shard_map(
        f, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    ref = (x.astype(jnp.float32) * 8).astype(jnp.bfloat16)
    if algo == "two_shot":
        res[f"psum_{algo}_exact"] = bits_equal(out, ref)
    else:
        rel = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32)))) / \
            float(jnp.max(jnp.abs(ref.astype(jnp.float32))))
        res[f"psum_{algo}_exact"] = rel < 5e-2  # bf16 per-hop rounding
    res[f"psum_{algo}_flag"] = int(flag)

# -- 2. all_to_all_compressed == raw all_to_all --------------------------------
a = jnp.asarray(rng.normal(0, 1, (8, 4096)), jnp.bfloat16)


def a2a_pair(v):
    vl = v.reshape(8, -1)  # local rows: one destination per device
    got, flag = all_to_all_compressed(vl, "data", policy=policy)
    want = jax.lax.all_to_all(vl.astype(jnp.float32), "data", 0, 0,
                              tiled=False).astype(vl.dtype)
    return got.reshape(v.shape), want.reshape(v.shape), flag


g, w, flag = jax.jit(jax.shard_map(
    a2a_pair, mesh=mesh1, in_specs=(P("data", None),),
    out_specs=(P("data", None),) * 2 + (P(),),
    axis_names={"data"}, check_vma=False))(a)
res["a2a_exact"] = bits_equal(g, w)
res["a2a_flag"] = int(flag)

# -- 3. split_send / encode_send / chunked == raw ppermute ---------------------
perm = [(i, (i + 1) % 8) for i in range(8)]
t = jnp.asarray(rng.normal(0, 0.02, (1 << 15,)), jnp.bfloat16)
for name, fn in [("split", split_send), ("encode", encode_send),
                 ("chunked", chunked_pipeline_send)]:
    def f(v, _fn=fn):
        got, flag = _fn(v, "data", perm, width=5)
        want = jax.lax.ppermute(v, "data", perm)
        return got, want, flag

    g, w, flag = jax.jit(jax.shard_map(
        f, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P(), P()),
        axis_names={"data"}, check_vma=False))(t)
    res[f"p2p_{name}_exact"] = bits_equal(g, w)
    res[f"p2p_{name}_flag"] = int(flag)

# -- 4. tree_psum_compressed on a mixed pytree ---------------------------------
tree = {"w": jnp.asarray(rng.normal(0, 0.02, (256, 64)), jnp.bfloat16),
        "b": jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32),
        "n": jnp.asarray(rng.integers(0, 5, (3,)), jnp.int32)}


def tf(tr):
    out, flag = tree_psum_compressed(tr, "data", policy=policy)
    return out, flag


out, flag = jax.jit(jax.shard_map(
    tf, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P()),
    axis_names={"data"}, check_vma=False))(tree)
ok = bits_equal(out["w"], (tree["w"].astype(jnp.float32) * 8).astype(jnp.bfloat16))
ok &= bool(jnp.allclose(out["b"], tree["b"] * 8))
ok &= bool(jnp.all(out["n"] == tree["n"] * 8))
res["tree_psum_exact"] = ok

# -- 5. train-step losslessness on the 3-axis mesh (zero1 + fsdp) --------------
cfg = configs.get_smoke("smollm_135m")
batch = registry.make_batch(cfg, 8, 32)
batch = {k: jax.device_put(v, NamedSharding(mesh3, P(("pod", "data"), None)))
         for k, v in batch.items()}
for part, extra in [("zero1", {}), ("fsdp", {"fsdp_min_bytes": 0})]:
    tc = TrainConfig(microbatches=2, policy=CompressionPolicy(min_bytes=0),
                     optim=opt_lib.OptimConfig(lr=1e-3, warmup_steps=2),
                     partition=part, **extra)
    tr = dataclasses.replace(tc, policy=CompressionPolicy.disabled())
    s1, _ = build_train_state(cfg, tc, mesh3, jax.random.PRNGKey(1))
    s2, _ = build_train_state(cfg, tr, mesh3, jax.random.PRNGKey(1))
    f1, _ = build_train_step(cfg, tc, mesh3)
    f2, _ = build_train_step(cfg, tr, mesh3)
    j1, j2 = jax.jit(f1), jax.jit(f2)
    for _ in range(2):
        s1, m1 = j1(s1, batch)
        s2, m2 = j2(s2, batch)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        s1["params"], s2["params"])
    res[f"train_{part}_bitexact"] = max(
        jax.tree_util.tree_leaves(diffs)) == 0.0
    res[f"train_{part}_loss_drop"] = float(m2["loss"]) < 6.0

# -- 6. KV-cache transfer over a mesh axis --------------------------------------
from repro.models import transformer
cache = transformer.init_cache(cfg, 2, 64)
params = transformer.init(jax.random.PRNGKey(0), cfg)
_, cache = transformer.prefill(
    params, registry.make_batch(cfg, 2, 32), cfg, cache)


def kv(c):
    got, flag = transfer_cache(c, "data", perm, policy=policy)

    def raw(l):
        if l.ndim == 0:
            return jax.lax.ppermute(l[None], "data", perm)[0]
        return jax.lax.ppermute(l, "data", perm)

    want = jax.tree.map(raw, c)
    return got, want, flag


got, want, flag = jax.jit(jax.shard_map(
    kv, mesh=mesh1, in_specs=(P(),), out_specs=(P(), P(), P()),
    axis_names={"data"}, check_vma=False))(cache)
res["kv_transfer_exact"] = all(
    bits_equal(a, b) for a, b in zip(jax.tree_util.tree_leaves(got),
                                     jax.tree_util.tree_leaves(want)))

print("RESULT " + json.dumps(res))
