"""Wire-efficiency observatory: flight recorder, per-bucket wire ledger
+ width regret, drift detection, reporting, and the perf trajectory."""
import json
import os
import re
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import policy as policy_mod
from repro.obs import drift as drift_lib
from repro.obs import regret as regret_lib
from repro.obs.drift import DriftDetector
from repro.obs.recorder import FlightRecorder, sparkline


@pytest.fixture(autouse=True)
def _isolate():
    """Every test starts from an empty observatory, obs enabled."""
    obs.set_enabled(True)
    obs.reset()
    yield
    obs.set_enabled(None)  # restore the env-derived setting
    obs.reset()


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_recorder_window_and_eviction():
    rec = FlightRecorder(capacity=4)
    for v in range(10):
        rec.record("m", float(v + 1))
    got = rec.samples("m")
    assert [s.value for s in got] == [7.0, 8.0, 9.0, 10.0]  # ring evicted
    assert [s.step for s in got] == [7, 8, 9, 10]  # steps keep counting
    st = rec.window("m")
    assert (st.count, st.total, st.mean) == (4, 34.0, 8.5)
    assert (st.minimum, st.maximum, st.last) == (7.0, 10.0, 10.0)
    assert (st.first_step, st.last_step) == (7, 10)
    # n= trims within the retained ring
    assert [s.value for s in rec.samples("m", n=2)] == [9.0, 10.0]
    assert rec.window("missing") is None
    rec.clear()
    assert rec.series() == () and rec.record("m", 1.0) == 1  # step reset


def test_recorder_quantiles():
    rec = FlightRecorder(capacity=32)
    for v in range(1, 11):
        rec.record("m", float(v))
    st = rec.window("m")
    assert st.p50 == pytest.approx(5.5)
    assert st.p90 == pytest.approx(9.1)
    assert st.p99 == pytest.approx(9.91)


def test_recorder_label_kwargs_resolve_against_specs():
    rec = FlightRecorder(capacity=8)
    rec.record("plan_exec_total", 1.0, "kind=psum")
    got = rec.samples("plan_exec_total", kind="psum")  # kwargs -> spec order
    assert len(got) == 1 and got[0].value == 1.0
    assert rec.window("plan_exec_total", kind="psum").series == \
        "plan_exec_total|kind=psum"
    with pytest.raises(ValueError):
        rec.samples("plan_exec_total", wrong="x")
    with pytest.raises(ValueError):
        rec.samples("plan_exec_total", labels_key="kind=psum", kind="psum")


def test_registry_tee_feeds_recorder():
    """obs.metric() observations land in the flight recorder with the
    registry's exact series key — counters record the increment, gauges
    the level, histograms the observation, dec a negative value."""
    obs.metric("plan_exec_total").inc(kind="psum")
    obs.metric("plan_exec_total").inc(2, kind="psum")
    obs.metric("serve_queue_depth").inc()
    obs.metric("serve_queue_depth").dec()
    obs.metric("plan_wire_ratio").set(0.25, kind="psum")
    obs.metric("p2p_encode_seconds").observe(0.125, codec="width")
    rec = obs.recorder()
    assert [s.value for s in rec.samples("plan_exec_total", kind="psum")] \
        == [1.0, 2.0]
    assert [s.value for s in rec.samples("serve_queue_depth")] == [1.0, -1.0]
    assert [s.value for s in rec.samples("plan_wire_ratio", kind="psum")] \
        == [0.25]
    assert [s.value for s in rec.samples("p2p_encode_seconds",
                                         codec="width")] == [0.125]
    # the tee still validates: bad labels raise, nothing recorded
    with pytest.raises(ValueError):
        obs.metric("plan_exec_total").inc(wrong="x")
    # registry values unaffected by the tee
    assert obs.snapshot()["counters"]["plan_exec_total"] == {"kind=psum": 3}


def test_recorder_thread_safety():
    rec = FlightRecorder(capacity=1000)

    def worker(i):
        for _ in range(250):
            rec.record("m", 1.0, f"t={i}")

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    per = [rec.samples("m", labels_key=f"t={i}") for i in range(4)]
    assert [len(p) for p in per] == [250] * 4
    steps = sorted(s.step for p in per for s in p)
    assert steps == list(range(1, 1001))  # globally unique, gap-free


def test_sparkline():
    assert sparkline([]) == ""
    assert sparkline([1.0, 1.0]) == "▁▁"  # flat series
    s = sparkline([0, 1, 2, 3])
    assert len(s) == 4 and s[0] == "▁" and s[-1] == "█"


# ---------------------------------------------------------------------------
# per-bucket wire ledger: exact agreement with the roofline summary
# ---------------------------------------------------------------------------

def _run_plan_psum():
    from jax.sharding import PartitionSpec as P

    from repro import sched
    from repro.core.policy import CompressionPolicy

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    pol = CompressionPolicy(min_bytes=0)
    cache = sched.PlanCache()
    tree = {"w": jnp.arange(4096, dtype=jnp.float32)}

    def fn(t):
        return sched.psum_with_plan(t, "data", policy=pol, cache=cache)

    f = jax.shard_map(fn, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
                      axis_names={"data"}, check_vma=False)
    return f(tree)


def test_bucket_ledger_agrees_exactly_with_wire_reports():
    """The acceptance contract: the per-bucket ledger sums to EXACTLY the
    consolidated plan:* WireReport totals (the executor re-forwards each
    bucket capture), so regret analytics and the roofline agree."""
    from repro.roofline.analysis import summarize_wire_reports

    policy_mod.clear_wire_reports()
    _run_plan_psum()
    reports = policy_mod.wire_reports()
    res = regret_lib.check_ledger_exactness(reports)
    assert res["ok"], res["diffs"]
    summ = summarize_wire_reports(
        [r for r in reports if r.name.startswith("plan:")])
    led = regret_lib.ledger_totals()
    assert led["by_kind"]["psum"]["raw_bytes"] == summ["raw_bytes"]
    assert led["by_kind"]["psum"]["wire_bytes"] == summ["wire_bytes"]
    # ledger rows carry real (kind, dtype, width) coordinates
    assert all(k == "psum" and d == "float32"
               for (k, d, _) in led["by_bucket"])


def test_ledger_exactness_flags_diffs():
    """A ledger entry with no plan counterpart is a reported diff."""
    obs.metric("bucket_wire_raw_bytes_total").inc(
        100, kind="psum", dtype="float32", width=5)
    obs.metric("bucket_wire_bytes_total").inc(
        40, kind="psum", dtype="float32", width=5)
    res = regret_lib.check_ledger_exactness([])
    assert not res["ok"] and "psum" in res["diffs"]


def test_plan_wire_ratio_hist_and_drift_observation():
    """One plan execution populates the labeled ratio histogram (satellite
    2) and feeds the drift detector with a zero-excess observation —
    static executor wires match their prediction exactly, so stationary
    traffic can never fire it."""
    _run_plan_psum()
    snap = obs.snapshot()
    h = snap["histograms"]["plan_wire_ratio_hist"]["kind=psum"]
    assert h["count"] == 1
    assert snap["gauges"]["plan_wire_ratio"]["kind=psum"] == \
        pytest.approx(h["sum"])  # gauge kept alongside the histogram
    # the tee recorded the ratio series for sparkline reports
    assert len(obs.recorder().samples("plan_wire_ratio_hist",
                                      kind="psum")) == 1
    st = drift_lib.detector()._state
    assert len(st) == 1
    (key, ks), = st.items()
    assert ks.kind == "psum" and list(ks.ring) == [pytest.approx(1.0)]
    assert drift_lib.detector().report().events == ()


# ---------------------------------------------------------------------------
# host-path ledger + samples + width regret
# ---------------------------------------------------------------------------

def _sync_workload(n=4096, warm=3, shifted=0, shift_scale=0.5):
    from benchmarks.fig_sync import _calibrated_policy, _make_params, \
        _optimizer_step

    from repro.sync import WeightSyncEngine, apply_update

    params = _make_params(n, seed=7)
    v1 = _optimizer_step(params, 2e-4, seed=8)
    policy, _ = _calibrated_policy(params, v1)
    eng = WeightSyncEngine(policy=policy)
    held = None
    modes = []
    for it in range(warm + shifted):
        if 0 < it < warm:
            params = _optimizer_step(params, 2e-4, seed=10 + it)
        elif it >= warm:
            params = _optimizer_step(params, shift_scale, seed=50 + it)
        eng.publish(params)
        upd = eng.update_for("r0")
        held = apply_update(upd, base_params=held
                            if upd.base_version is not None else None)
        eng.ack("r0", upd.version, upd.epoch)
        modes.append(upd.mode)
    return modes


def test_wsync_host_ledger_samples_and_regret():
    modes = _sync_workload(warm=3)
    assert "delta" in modes  # the warm loop actually took the delta path
    led = regret_lib.ledger_totals()
    assert "wsync_host" in led["by_kind"]
    assert led["by_kind"]["wsync_host"]["raw_bytes"] > 0
    assert 0 < led["by_kind"]["wsync_host"]["ratio"] < 1
    # host kinds stay OUT of the plan-kind exactness check
    assert regret_lib.check_ledger_exactness([])["ok"]
    samp = regret_lib.samples()
    assert ("wsync_host", "bfloat16") in samp
    assert any(e.base is not None for e in samp[("wsync_host", "bfloat16")])
    rows = regret_lib.width_regret()
    assert rows and rows[0].kind == "wsync_host"
    r = rows[0]
    assert r.dtype_name == "bfloat16" and r.n_samples >= 1
    assert r.achieved_raw_bytes > 0 and r.optimal_width >= 1
    assert r.regret_bytes == r.achieved_wire_bytes - r.optimal_wire_bytes
    assert r.optimal_delta_widths is not None  # delta-base pair retained
    d = r.to_dict()
    json.dumps(d)  # report row must be JSON-clean


def test_sample_store_downsamples_and_bounds():
    big = np.arange(regret_lib.SAMPLE_MAX_ELEMS * 4, dtype=np.float32)
    regret_lib.record_sample("k", "float32", big, base=big + 1)
    (s,) = regret_lib.samples()[("k", "float32")]
    assert s.elems == big.size and s.x.size <= regret_lib.SAMPLE_MAX_ELEMS
    assert np.all(s.base == s.x + 1)  # element pairing survives the stride
    for i in range(regret_lib.SAMPLE_CAPACITY + 3):
        regret_lib.record_sample("k", "float32", np.ones(4) * i)
    ring = regret_lib.samples()[("k", "float32")]
    assert len(ring) == regret_lib.SAMPLE_CAPACITY  # bounded


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------

def test_drift_fires_once_rearms_and_refires():
    det = DriftDetector(window=4, min_count=2, enter=0.2, exit=0.05)
    assert not any(det.observe("k", "psum", 0.5, 0.5) for _ in range(5))
    fired = [det.observe("k", "psum", 0.5, 1.0) for _ in range(4)]
    assert sum(fired) == 1  # once per excursion, however long it lasts
    rep = det.report()
    assert len(rep.events) == 1 and len(rep.stale) == 1
    ev = rep.events[0]
    assert ev.kind == "psum" and ev.live_ratio > ev.predicted_ratio
    assert rep.stale[0].key_hex == ev.key_hex
    # recovery re-arms (window refills with matching traffic) ...
    for _ in range(6):
        det.observe("k", "psum", 0.5, 0.5)
    assert det.report().stale == ()
    # ... and a second excursion fires a second event
    assert sum(det.observe("k", "psum", 0.5, 1.0) for _ in range(4)) == 1
    assert len(det.report().events) == 2
    # the default detector's firings also hit the metric + instant span
    assert drift_lib.observe("m", "wsync", 0.1, 1.0) is False  # min_count
    drift_lib.observe("m", "wsync", 0.1, 1.0)
    assert drift_lib.observe("m", "wsync", 0.1, 1.0) is True
    snap = obs.snapshot()
    # every firing (the scripted detector's two psum excursions included)
    # hits the shared counter, labeled by plan kind
    assert snap["counters"]["wire_drift_events_total"] == \
        {"kind=psum": 2, "kind=wsync": 1}
    assert any(s.name == "drift:fire" for s in obs.spans())


def test_drift_min_count_and_bad_prediction():
    det = DriftDetector(window=8, min_count=3)
    assert det.observe("k", "psum", 0.5, 5.0) is False
    assert det.observe("k", "psum", 0.5, 5.0) is False  # still < min_count
    assert det.observe("k", "psum", 0.5, 5.0) is True
    assert det.observe("k2", "psum", 0.0, 5.0) is False  # no prediction
    assert det.observe("k2", "psum", 0.0, 5.0) is False
    assert det.observe("k2", "psum", 0.0, 5.0) is False
    with pytest.raises(ValueError):
        DriftDetector(enter=0.1, exit=0.2)  # hysteresis must open downward


def test_drift_stationary_jitter_never_fires():
    det = DriftDetector()
    for i in range(50):
        live = 0.5 * (1.01 if i % 2 else 0.99)  # +/-1% measurement noise
        assert det.observe("k", "psum", 0.5, live) is False
    assert det.report().events == ()


def test_drift_mode_transition_is_not_drift():
    """Regression: the window holds live/predicted residuals, so a
    legitimate prediction change (full send -> cheap delta once a base is
    acked) must not read old full-ratio observations as drift against the
    new delta prediction."""
    det = DriftDetector()
    det.observe("k", "wsync", 0.8, 0.8)  # full-send regime
    for _ in range(10):
        assert det.observe("k", "wsync", 0.2, 0.2) is False  # delta regime
    assert det.report().events == ()


def test_sync_engine_drift_fires_on_entropy_shift():
    """End-to-end: warm deltas match the plan's prediction; a shifted
    update distribution overflows into full sends and the detector names
    the plan stale."""
    modes = _sync_workload(warm=4, shifted=2)
    assert modes[-1] == "full"  # the shift really forced the fallback
    rep = drift_lib.detector().report()
    assert len(rep.events) >= 1
    assert rep.events[0].kind == "wsync"
    assert rep.stale and rep.stale[0].live_ratio > rep.stale[0].predicted_ratio
    snap = obs.snapshot()
    assert snap["counters"]["wire_drift_events_total"]["kind=wsync"] >= 1


# ---------------------------------------------------------------------------
# disabled mode: the whole observatory no-ops
# ---------------------------------------------------------------------------

def test_disabled_mode_noops():
    obs.set_enabled(False)
    obs.metric("plan_exec_total").inc(kind="psum")
    assert obs.recorder().series() == ()  # no tee
    regret_lib.record_sample("k", "float32", np.zeros(8))
    assert regret_lib.samples() == {}
    assert drift_lib.observe("k", "psum", 0.5, 5.0) is False
    assert drift_lib.observe("k", "psum", 0.5, 5.0) is False
    assert drift_lib.observe("k", "psum", 0.5, 5.0) is False
    assert drift_lib.detector().report() == drift_lib.DriftReport((), ())
    with pytest.raises(KeyError):
        obs.metric("not_a_metric")  # typo check stays on while disabled


def test_clear_observatory_keeps_registry():
    obs.metric("plan_exec_total").inc(kind="psum")
    regret_lib.record_sample("k", "float32", np.zeros(8))
    drift_lib.observe("k", "psum", 0.5, 5.0)
    obs.clear_observatory()
    assert obs.recorder().series() == ()
    assert regret_lib.samples() == {}
    assert drift_lib.detector()._state == {}
    # the registry itself is NOT part of the observatory clear
    assert obs.snapshot()["counters"]["plan_exec_total"] == {"kind=psum": 1}


# ---------------------------------------------------------------------------
# static guard: every obs name literal in the runtime resolves
# ---------------------------------------------------------------------------

def test_every_obs_name_literal_resolves():
    """Grep every string-literal obs.metric/span/instant call under
    src/repro/ and resolve it against obs.names — an instrumented call
    site cannot reference a name the registry does not declare.
    (f-string call sites like plan:<kind> are covered by the span-name
    table test instead.)"""
    from repro.obs import names
    from repro.sched.compile import PLAN_KINDS

    span_names = {n for n, _, _ in names.SPANS}
    # "plan:<kind>" is a templated family: accept its instantiations
    span_names |= {f"plan:{k}" for k in PLAN_KINDS}
    pat = re.compile(
        r"""obs\s*\.\s*(metric|span|instant)\(\s*["']([^"']+)["']""")
    src = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    unknown, hits = [], 0
    for root, _, files in os.walk(src):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            with open(os.path.join(root, fn)) as f:
                text = f.read()
            for what, name in pat.findall(text):
                hits += 1
                table = names.SPECS if what == "metric" else span_names
                if name not in table:
                    unknown.append((fn, what, name))
    assert hits > 30, "the grep found implausibly few call sites"
    assert not unknown, f"unresolvable obs names: {unknown}"


# ---------------------------------------------------------------------------
# reporting surface + perf trajectory
# ---------------------------------------------------------------------------

def test_dump_report_artifacts(tmp_path):
    from repro.obs import dump as dump_mod

    paths = dump_mod.dump("sync", str(tmp_path), steps=2, report=True)
    assert set(paths) >= {"report_json", "report_md"}
    rep = json.load(open(paths["report_json"]))
    assert set(rep) >= {"regret", "drift", "ledger_by_kind",
                        "ledger_by_bucket", "ratio_series"}
    assert any(k.startswith("wsync_host/") for k in rep["ledger_by_bucket"])
    md = open(paths["report_md"]).read()
    assert md.startswith("# Wire-efficiency observatory")
    assert "regret" in md and "Drift" in md


def test_append_trajectory(tmp_path):
    from benchmarks.common import append_trajectory

    path = str(tmp_path / "traj.json")
    append_trajectory({"date": "d1", "source": "s"}, path)
    append_trajectory({"date": "d2", "source": "s"}, path)
    recs = json.load(open(path))
    assert [r["date"] for r in recs] == ["d1", "d2"]
    with open(path, "w") as f:
        f.write("not json{")
    append_trajectory({"date": "d3", "source": "s"}, path)  # recovers
    assert [r["date"] for r in json.load(open(path))] == ["d3"]
