"""Interleaved rANS codec: round-trips, tables, paper-claimed ratios."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st  # hypothesis or fallback

from repro.core import ans, codec


def test_table_sums_to_M():
    rng = np.random.default_rng(0)
    for data in [rng.integers(0, 256, 5000), np.full(100, 7), np.arange(256)]:
        t = ans.build_freq_table(jnp.asarray(data.astype(np.uint8)))
        assert int(t.freq.sum()) == ans.M
        assert int(t.freq.min()) >= 1  # every symbol encodable (sampled tables)


@pytest.mark.parametrize(
    "gen",
    [
        lambda r: np.clip(r.normal(120, 3, 4000), 0, 255),
        lambda r: r.integers(0, 256, 4000),
        lambda r: np.full(4000, 42),
        lambda r: np.concatenate([np.zeros(2000), np.full(2000, 255)]),
    ],
    ids=["skewed", "uniform", "const", "bimodal"],
)
def test_roundtrip_distributions(gen):
    rng = np.random.default_rng(1)
    syms = jnp.asarray(gen(rng).astype(np.uint8))
    assert ans.roundtrip_exact(syms)


@pytest.mark.parametrize("n", [1, 2, 127, 128, 129, 1000])
@pytest.mark.parametrize("lanes", [4, 128])
def test_roundtrip_sizes(n, lanes):
    rng = np.random.default_rng(n)
    syms = jnp.asarray(rng.integers(100, 140, n).astype(np.uint8))
    assert ans.roundtrip_exact(syms, lanes=lanes)


@given(st.lists(st.integers(0, 255), min_size=1, max_size=400))
@settings(max_examples=25, deadline=None)
def test_roundtrip_property(data):
    syms = jnp.asarray(np.asarray(data, np.uint8))
    assert ans.roundtrip_exact(syms, lanes=8)


def test_sampled_table_is_lossless():
    """Paper §3.3.1: localized tables from a sampled prefix must stay
    lossless even when rare symbols were unseen in the sample."""
    rng = np.random.default_rng(2)
    syms = np.clip(rng.normal(120, 2, 20000), 0, 255).astype(np.uint8)
    syms[-1] = 255  # rare symbol, absent from the sample prefix
    syms = jnp.asarray(syms)
    table = ans.build_freq_table(syms[:1024])
    out = ans.decode(ans.encode(syms, table))
    assert (out == syms).all()


def test_bf16_ratio_matches_paper():
    """Uniform [-1,1] bf16 (paper §5.2.1): total ratio ~= 0.64."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(-1, 1, 1 << 17), jnp.bfloat16)
    exp, lo = codec.split_planes(x)
    st_ = ans.encode(exp, ans.build_freq_table(exp))
    total_ratio = (lo.size + float(st_.compressed_nbytes())) / (x.size * 2)
    assert abs(total_ratio - 0.64) < 0.03, total_ratio


def test_table_reuse_across_steps():
    """Paper §3.4: one table serves subsequent steps of the same tensor."""
    rng = np.random.default_rng(4)
    x0 = jnp.asarray(rng.normal(0, 1, 8192), jnp.bfloat16)
    x1 = jnp.asarray(rng.normal(0, 1.05, 8192), jnp.bfloat16)  # drifted step
    e0, _ = codec.split_planes(x0)
    e1, _ = codec.split_planes(x1)
    table = ans.build_freq_table(e0)
    out = ans.decode(ans.encode(e1, table))  # old table, new data
    assert (out == e1).all()


def test_ratio_estimate_tracks_actual():
    rng = np.random.default_rng(5)
    syms = jnp.asarray(np.clip(rng.normal(120, 3, 1 << 15), 0, 255).astype(np.uint8))
    est = float(ans.ans_ratio_estimate(syms))
    st_ = ans.encode(syms, ans.build_freq_table(syms))
    actual = float(st_.compressed_nbytes()) * 8 / syms.size
    assert abs(est - actual) < 0.6  # flush+table overhead only
