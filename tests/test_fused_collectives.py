"""Fused decode+reduce receive path: bit-identical to the unfused path.

Chunk-level tests drive the collective internals directly (no mesh): the
wire dicts produced by ``_encode_chunks`` are exactly what arrives after
the all_to_all, so ``_decode_reduce_chunks`` (fused) vs ``_decode_chunks``
+ ``_seq_sum`` (unfused) is the receive-side comparison the paper's §3.4
makes.  Mesh-level parity across 8 real devices lives in test_multidev.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec, packing
from repro.core import compressed_collectives as cc
from repro.core import policy as policy_lib
from repro.kernels import ops, ref
from repro.kernels.decode_reduce import TILE_G

DTYPES = ["bfloat16", "float32", "float16"]


def bits32(a):
    return jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.uint32)


def make_chunks(dt_name, n_chunks, chunk, seed=0, zeros=0.05, poison=()):
    """Realistic gradient-like chunks with exact zeros and optional poison
    values that force exception blocks."""
    lay = codec.LAYOUTS[dt_name]
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 0.02, (n_chunks, chunk))
    x[rng.random((n_chunks, chunk)) < zeros] = 0.0
    for (c, i, v) in poison:
        x[c, i] = v
    return jnp.asarray(x, lay.dtype)


def fused_vs_unfused(x, width, *, block=512, exc_frac=0.02, use_pallas=False):
    chunk = x.shape[1]
    wire = cc._encode_chunks(x, width=width, block=block, exc_frac=exc_frac)
    vals, f1 = cc._decode_chunks(wire, dtype=x.dtype, n=chunk, width=width,
                                 block=block)
    unfused = cc._seq_sum(vals, jnp.float32)
    fused, f2 = cc._decode_reduce_chunks(wire, dtype=x.dtype, n=chunk,
                                         width=width, block=block,
                                         use_pallas=use_pallas)
    return unfused, fused, int(f1), int(f2)


@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("width", [3, 4, 5])
def test_fused_bit_identical(dt, width):
    x = make_chunks(dt, 4, 4096, seed=width)
    unfused, fused, f1, f2 = fused_vs_unfused(x, width)
    assert f1 == f2
    assert (bits32(unfused) == bits32(fused)).all()


@pytest.mark.parametrize("dt", ["bfloat16", "float32"])
def test_fused_exception_blocks_exact(dt):
    """Poisoned wide-dynamic-range blocks ride the exception region; the
    fused patch-up must reproduce the unfused result bit-for-bit AND the
    true f32 sum (flag stays 0: capacity covers the poisons)."""
    hi, lo = (1e30, 1e-30)
    x = make_chunks(dt, 3, 4096, seed=1,
                    poison=[(0, 100, hi), (1, 700, lo), (2, 700, -hi)])
    unfused, fused, f1, f2 = fused_vs_unfused(x, width=4)
    assert f1 == 0 and f2 == 0
    assert (bits32(unfused) == bits32(fused)).all()
    truth = cc._seq_sum(x, jnp.float32)
    assert (bits32(truth) == bits32(fused)).all()


def test_fused_overflow_flag_and_parity():
    """Wild-but-finite data at a tiny width overflows exception capacity:
    the flag must fire on BOTH paths and the outputs still agree bitwise
    (the caller discards them and retries uncompressed either way)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(2.0 ** rng.uniform(-30, 30, (2, 4096)), jnp.bfloat16)
    wire = cc._encode_chunks(x, width=2, block=512, exc_frac=0.01)
    vals, f1 = cc._decode_chunks(wire, dtype=x.dtype, n=4096, width=2,
                                 block=512)
    unfused = cc._seq_sum(vals, jnp.float32)
    fused, f2 = cc._decode_reduce_chunks(wire, dtype=x.dtype, n=4096,
                                         width=2, block=512)
    assert int(f1) == 1 and int(f2) == 1
    assert (bits32(unfused) == bits32(fused)).all()


def test_fused_pallas_kernel_path():
    """TILE_G-aligned chunks take the Pallas kernel (interpret mode on CPU)
    and must match the unfused path bitwise."""
    chunk = 32 * TILE_G  # n_groups == TILE_G: kernel-aligned
    x = make_chunks("bfloat16", 2, chunk, seed=4)
    unfused, fused, f1, f2 = fused_vs_unfused(x, width=5, use_pallas=True)
    assert (bits32(unfused) == bits32(fused)).all()


def test_tile_misaligned_falls_back():
    """n_groups % TILE_G != 0: ops.decode_reduce must route to the fused
    jnp reference (same semantics) instead of the Pallas kernel."""
    chunk = 512 * 3  # 48 groups: not a TILE_G multiple
    x = make_chunks("bfloat16", 2, chunk, seed=5)
    unfused, fused, f1, f2 = fused_vs_unfused(x, width=5, use_pallas=True)
    assert (bits32(unfused) == bits32(fused)).all()


def test_acc_dtype_fallback_unfused():
    """Non-f32 accumulation has no fused kernel: reduce_scatter_compressed
    must fall back without error (1-device axis via shard_map)."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(np.random.default_rng(6).normal(0, 1, 2048), jnp.bfloat16)
    out, flag = jax.jit(jax.shard_map(
        lambda v: cc.reduce_scatter_compressed(
            v, "data", width=5, acc_dtype=jnp.bfloat16, use_fused=True),
        mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    assert int(flag) == 0


def test_reduce_scatter_roundtrip_one_device():
    """k=1 reduce-scatter == exact decode of own chunk (fused path)."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(np.random.default_rng(7).normal(0, 0.02, 4096),
                    jnp.bfloat16)
    out, flag = jax.jit(jax.shard_map(
        lambda v: cc.reduce_scatter_compressed(v, "data", width=5),
        mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(x)
    assert int(flag) == 0
    assert (bits32(x.astype(jnp.float32)) == bits32(out)).all()


def test_tree_psum_mixed_dtype_lossless_one_device():
    """{f32, bf16} pytree: per-dtype bucketing keeps every leaf bit-exact
    at its own precision (k=1: the sum is the identity, so any cast of the
    f32 leaf through bf16 — the old bug — would show up as bit drift)."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(8)
    tree = {
        "w_bf16": jnp.asarray(rng.normal(0, 0.02, (128, 32)), jnp.bfloat16),
        "b_f32": jnp.asarray(rng.normal(0, 1, (4096,)), jnp.float32),
        "h_f16": jnp.asarray(rng.normal(0, 1, (2048,)), jnp.float16),
        "step": jnp.asarray(7, jnp.int32),
    }
    pol = policy_lib.CompressionPolicy(min_bytes=0)
    out, flag = jax.jit(jax.shard_map(
        lambda t: cc.tree_psum_compressed(t, "data", policy=pol),
        mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))(tree)
    assert int(flag) == 0
    for k in ("w_bf16", "b_f32", "h_f16"):
        assert out[k].dtype == tree[k].dtype, k
        a = jax.lax.bitcast_convert_type(
            out[k], codec.layout_of(out[k].dtype).uint_dtype)
        b = jax.lax.bitcast_convert_type(
            tree[k], codec.layout_of(tree[k].dtype).uint_dtype)
        assert (a == b).all(), k
    assert int(out["step"]) == 7


def test_wire_reports_emitted_and_fused_flagged():
    """Tracing the two-shot over an abstract 8-device mesh emits WireReports
    whose fused flag follows the policy knob and whose decoded-HBM
    accounting moves from 'paid' to 'eliminated'."""
    from benchmarks.fig9_twoshot import trace_wire_reports
    from repro.roofline.analysis import summarize_wire_reports

    rs_fused = [r for r in trace_wire_reports(8, 1 << 18, fused=True)
                if r.name == "reduce_scatter"]
    rs_unfused = [r for r in trace_wire_reports(8, 1 << 18, fused=False)
                  if r.name == "reduce_scatter"]
    assert rs_fused and rs_unfused
    assert all(r.fused for r in rs_fused)
    assert not any(r.fused for r in rs_unfused)
    assert all(0 < r.wire_bytes < r.raw_bytes for r in rs_fused)
    s_f = summarize_wire_reports(rs_fused)
    s_u = summarize_wire_reports(rs_unfused)
    assert s_f["decode_hbm_eliminated"] > 0 and s_f["decode_hbm_paid"] == 0
    assert s_u["decode_hbm_paid"] == s_f["decode_hbm_eliminated"]


def test_decode_reduce_kernel_zero_escape_matches_wire_format():
    """The kernel decodes the REAL wire (pack_exponents zero-escape) —
    non-exception data must match unpack_exponents + merge + add exactly."""
    lay = codec.LAYOUTS["bfloat16"]
    rng = np.random.default_rng(9)
    n = 32 * TILE_G
    x = jnp.asarray(rng.normal(0, 0.02, n), jnp.bfloat16)
    x = x.at[:n // 4].set(0.0)  # exercise the zero escape heavily
    exp, lo = codec.split_planes(x)
    pk = packing.pack_exponents(exp, width=8, block=512)  # w=8: no escapes
    assert int(pk.overflow) == 0
    gb = jnp.repeat(pk.bases.astype(jnp.uint32), 512 // packing.GROUP)
    lo_planes = packing.bitplane_pack(lo.astype(jnp.uint32), lay.lo_bits)
    acc = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = ops.decode_reduce(pk.payload, lo_planes, gb, acc, "bfloat16", 8,
                            use_pallas=True)
    want_vals = codec.merge_planes(packing.unpack_exponents(pk),
                                   lo.astype(lay.uint_dtype),
                                   lay.dtype, (n,))
    want = acc + want_vals.astype(jnp.float32)
    assert (bits32(got) == bits32(want)).all()
    # and the jnp reference agrees with the kernel
    got_ref = ref.decode_reduce(pk.payload, lo_planes, gb, acc, "bfloat16", 8)
    assert (bits32(got_ref) == bits32(got)).all()
