"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-loss / prefill / decode step on CPU, asserting shapes and finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry, transformer

ARCHS = configs.ARCHS


@pytest.fixture(scope="module")
def setup():
    out = {}
    for name in ARCHS:
        cfg = configs.get_smoke(name)
        params = transformer.init(jax.random.PRNGKey(0), cfg)
        out[name] = (cfg, params)
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(setup, name):
    cfg, params = setup[name]
    batch = registry.make_batch(cfg, 2, 32)
    h = transformer.forward(params, batch, cfg, remat=False)
    assert h.shape == (2, 32, cfg.d_model)
    logits = transformer.logits_from_hidden(params, h, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), name


@pytest.mark.parametrize("name", ARCHS)
def test_train_grads_finite(setup, name):
    cfg, params = setup[name]
    batch = registry.make_batch(cfg, 2, 16)

    def loss(p):
        h = transformer.forward(p, batch, cfg, remat=True)
        logits = transformer.logits_from_hidden(p, h, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                                   -1)[..., 0]
        return jnp.mean(lse - gold)

    l, g = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l)), name
    assert np.log(cfg.vocab) * 0.2 < float(l) < np.log(cfg.vocab) * 3
    finite = all(bool(jnp.isfinite(x.astype(jnp.float32)).all())
                 for x in jax.tree_util.tree_leaves(g))
    assert finite, f"{name}: non-finite gradients"


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_matches_forward(setup, name):
    cfg, params = setup[name]
    batch = registry.make_batch(cfg, 2, 16)
    cache = transformer.init_cache(cfg, 2, 32)
    lg_p, cache = transformer.prefill(params, batch, cfg, cache)
    h = transformer.forward(params, batch, cfg, remat=False)
    lg_f = transformer.logits_from_hidden(params, h[:, -1:], cfg)
    err = float(jnp.max(jnp.abs(lg_p.astype(jnp.float32)
                                - lg_f.astype(jnp.float32))))
    assert err < 1e-4, (name, err)
    assert int(cache["pos"]) == 16


@pytest.mark.parametrize("name", ARCHS)
def test_decode_agrees_with_prefill(setup, name):
    cfg, params = setup[name]
    cfg32 = dataclasses.replace(cfg, dtype="float32")
    params32 = jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params)
    batch = registry.make_batch(cfg32, 2, 8)
    batch.pop("vision_embeds", None)  # decode path carries no vision stub
    enc_out = None
    if cfg.enc_dec:
        enc_out = transformer._run_encoder(params32, batch["frames"], cfg32)
    cache = transformer.init_cache(cfg32, 2, 16)
    lg_p, _ = transformer.prefill(params32, batch, cfg32, cache)
    cache2 = transformer.init_cache(cfg32, 2, 16)
    lg_d = None
    for t in range(8):
        lg_d, cache2 = transformer.decode_step(
            params32, batch["tokens"][:, t:t + 1], cache2, cfg32,
            enc_out=enc_out)
    scale = float(jnp.max(jnp.abs(lg_p))) + 1e-6
    rel = float(jnp.max(jnp.abs(lg_p - lg_d))) / scale
    assert rel < 1e-3, (name, rel)


@pytest.mark.parametrize("name", ARCHS)
def test_param_count_matches_shapes(setup, name):
    """Analytic 6ND param count must equal the real init's element count."""
    cfg, params = setup[name]
    actual = sum(int(np.prod(l.shape))
                 for l in jax.tree_util.tree_leaves(params))
    assert actual == cfg.param_count(), (
        name, actual, cfg.param_count())


def test_full_configs_match_assignment():
    """Spot-check the FULL configs against the assignment table."""
    c = configs.get("tinyllama_1_1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads, c.d_ff,
            c.vocab) == (22, 2048, 32, 4, 5632, 32000)
    c = configs.get("deepseek_v3_671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (61, 7168, 128,
                                                           129280)
    assert c.moe.n_experts == 256 and c.moe.top_k == 8 and c.moe.n_shared == 1
    assert c.mla.kv_lora == 512
    c = configs.get("gemma3_27b")
    assert c.n_layers == 62 and c.vocab == 262144
    # 5:1 local:global pattern
    assert sum(1 for s in c.pattern if s.window is None) * 5 == sum(
        1 for s in c.pattern if s.window is not None)
    c = configs.get("jamba_v0_1_52b")
    assert c.n_layers == 32
    n_attn = sum(1 for s in (list(c.prefix) + list(c.pattern) * c.repeats)
                 if s.mixer == "attn")
    n_mamba = sum(1 for s in (list(c.prefix) + list(c.pattern) * c.repeats)
                  if s.mixer == "mamba")
    assert n_mamba == 7 * n_attn  # 1:7 attn:mamba
    c = configs.get("whisper_small")
    assert c.enc_dec and c.n_layers == 12 and c.d_model == 768
    c = configs.get("xlstm_350m")
    assert {s.mixer for s in c.pattern} == {"mlstm", "slstm"}
    c = configs.get("qwen2_vl_72b")
    assert c.n_layers == 80 and c.d_model == 8192 and c.frontend == "vision_stub"


@pytest.mark.parametrize("name", ["tinyllama_1_1b", "deepseek_v2_lite_16b",
                                  "jamba_v0_1_52b"])
def test_active_params_less_than_total_for_moe(name):
    cfg = configs.get(name)
    if any(s.ffn == "moe" for s in cfg.pattern):
        assert cfg.active_param_count() < cfg.param_count()
    else:
        assert cfg.active_param_count() == cfg.param_count()
