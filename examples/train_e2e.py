"""End-to-end training driver example: train the ~135M-param smollm-135m
(REAL config, not the smoke twin) for a few hundred steps on CPU with the
full production stack: data pipeline -> compressed ZeRO-1 step ->
fault-tolerant runner (checkpoints, retry, straggler metrics) -> resume.

    PYTHONPATH=src python examples/train_e2e.py --steps 200

Note: this is the deliverable's "train ~100M model for a few hundred
steps" driver.  On CPU a step at seq 256/batch 8 takes a few seconds; use
--steps to trade time for curve length."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.policy import CompressionPolicy
from repro.data.pipeline import DataConfig, DataPipeline
from repro.launch.mesh import make_smoke_mesh
from repro.optim import optimizers as opt_lib
from repro.runtime.fault_tolerance import RunnerConfig, StepRunner
from repro.train import step as step_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = configs.get("smollm_135m")  # full 135M config
    mesh = make_smoke_mesh()
    tcfg = step_lib.TrainConfig(
        microbatches=1,
        policy=CompressionPolicy(min_bytes=1 << 20),
        optim=opt_lib.OptimConfig(lr=6e-4, warmup_steps=50,
                                  decay_steps=args.steps),
        loss_chunk=min(1024, args.seq),
    )
    print(f"smollm-135m: {cfg.param_count()/1e6:.1f}M params, mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}, compressed "
          f"gradient sync (two-shot ZeRO-1)")
    step, _ = step_lib.build_train_step(cfg, tcfg, mesh)
    import dataclasses
    raw_tcfg = dataclasses.replace(tcfg, policy=CompressionPolicy.disabled())
    fallback, _ = step_lib.build_train_step(cfg, raw_tcfg, mesh)
    state, _ = step_lib.build_train_state(cfg, tcfg, mesh,
                                          jax.random.PRNGKey(0))

    pipe = DataPipeline(DataConfig(vocab=cfg.vocab, global_batch=args.batch,
                                   seq_len=args.seq, seed=0))
    shutil.rmtree(args.ckpt, ignore_errors=True)

    def wrap(fn):
        jfn = jax.jit(fn, donate_argnums=(0,))
        return lambda s, b: jfn(s, {k: jnp.asarray(v) for k, v in b.items()})

    runner = StepRunner(wrap(step), wrap(fallback),
                        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=50),
                        pipeline=pipe)
    state, hist = runner.train(state, num_steps=args.steps, log_every=20)
    print(f"\nloss {hist[0]:.3f} -> {hist[-1]:.3f} over {args.steps} steps "
          f"(retries={runner.retries}, stragglers={runner.stragglers})")
    assert hist[-1] < hist[0] - 0.5, "loss should drop substantially"
    # demonstrate restart-exactness: resume from checkpoint, take one step
    state2, start = runner.try_resume(jax.tree.map(
        lambda x: jnp.zeros_like(x), state))
    print(f"resume OK from step {start} (checkpoint round-trip verified)")


if __name__ == "__main__":
    main()
