"""Quickstart: the UCCL-Zip core in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. split a bf16 tensor into planes (paper Step 1) and inspect the skew,
2. compress it losslessly with the rANS coder and the static packed codec,
3. run a compressed all-reduce inside shard_map on the local mesh,
4. train a tiny model for 20 steps with the compressed two-shot gradient
   sync and confirm the loss curve matches the uncompressed twin exactly.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import ans, codec, packing
from repro.core.policy import CompressionPolicy
from repro.launch.mesh import make_smoke_mesh
from repro.models import registry
from repro.optim import optimizers as opt_lib
from repro.train import step as step_lib


def main():
    # -- 1. plane split -------------------------------------------------------
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.02, 1 << 20), jnp.bfloat16)  # weights
    exp, lo = codec.split_planes(x)
    ent = float(codec.exponent_entropy_bits(exp, 8))
    print(f"bf16 tensor: exponent entropy {ent:.2f} bits / 8 "
          f"(skewed -> compressible); lo plane {codec.plane_fractions(x.dtype)[0]*100:.0f}% of raw")

    # -- 2. lossless codecs ---------------------------------------------------
    table = ans.build_freq_table(exp)
    stream = ans.encode(exp[: 1 << 16], table)
    back = ans.decode(stream)
    assert (back == exp[: 1 << 16]).all()
    r_ans = (8 + float(ans.ans_ratio_estimate(exp))) / 16
    msg = packing.encode_message(x, width=5)
    y = packing.decode_message(msg)
    assert (jax.lax.bitcast_convert_type(x, jnp.uint16)
            == jax.lax.bitcast_convert_type(y, jnp.uint16)).all()
    print(f"rANS ratio {r_ans:.3f} (paper bf16 ≈ 0.675) | "
          f"packed-width ratio {msg.ratio():.3f} (static-shape wire) | "
          f"both bit-exact")

    # -- 3. compressed all-reduce --------------------------------------------
    from repro.core.compressed_collectives import psum_compressed
    mesh = make_smoke_mesh()
    policy = CompressionPolicy(min_bytes=0)
    g = jnp.asarray(rng.normal(0, 1e-3, 1 << 18), jnp.bfloat16)

    def sync(v):
        out, flag = psum_compressed(v, "data", policy=policy)
        return out, flag

    f = jax.jit(jax.shard_map(
        sync, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        axis_names={"data"}, check_vma=False))
    out, flag = f(g)
    ref = g.astype(jnp.float32) * mesh.shape["data"]
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref)))
    print(f"compressed two-shot all-reduce on mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}: "
          f"max err {err:.2e}, overflow {int(flag)}")

    # -- 4. compressed vs raw training: identical curves ----------------------
    cfg = configs.get_smoke("smollm_135m")
    mesh = make_smoke_mesh()
    mk = lambda pol: step_lib.TrainConfig(
        microbatches=1, policy=pol,
        optim=opt_lib.OptimConfig(lr=1e-3, warmup_steps=5))
    batch = registry.make_batch(cfg, 4, 64)
    curves = {}
    for name, pol in [("compressed", CompressionPolicy(min_bytes=0)),
                      ("raw", CompressionPolicy.disabled())]:
        tcfg = mk(pol)
        step, _ = step_lib.build_train_step(cfg, tcfg, mesh)
        state, _ = step_lib.build_train_state(cfg, tcfg, mesh,
                                              jax.random.PRNGKey(7))
        jstep = jax.jit(step, donate_argnums=(0,))
        losses = []
        for _ in range(20):
            state, m = jstep(state, batch)
            losses.append(float(m["loss"]))
        curves[name] = losses
    same = all(a == b for a, b in zip(curves["compressed"], curves["raw"]))
    print(f"20-step training curves identical: {same} "
          f"(final loss {curves['compressed'][-1]:.4f}) — lossless end-to-end")


if __name__ == "__main__":
    main()
