"""RL weight synchronization with UZIP-P2P (paper §5.3.1, Fig. 10).

    PYTHONPATH=src python examples/rl_weight_sync.py

The paper's headline P2P workload: an RL pipeline where 4 trainer GPUs push
updated policy weights to 4 rollout GPUs every iteration.  Here a GLM4-9B
(the paper's model) smoke twin is trained for a few steps; after each
update phase the full weight pytree is shipped through the host P2P engine
with split-send compression, decoded on the "rollout" side, and verified
bit-exact.  Reported: per-tensor ratio/throughput (paper: +47.5% on the
214 MB gate_up_proj) under the 50 GB/s link model, plus real CPU codec
times."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.policy import CompressionPolicy
from repro.launch.mesh import make_smoke_mesh
from repro.models import registry, transformer
from repro.optim import optimizers as opt_lib
from repro.p2p.engine import CodecModel, Compressor, WireModel
from repro.train import step as step_lib


def sync_weights(params, eng, wire, cm):
    """Trainer -> rollout: bucket ALL weights into one flat message per
    dtype (paper Property 1: large blocks keep the codec efficient),
    encode, (modelled) wire at H200 codec rates, decode, verify."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    groups = {}
    for i, l in enumerate(leaves):
        groups.setdefault(jnp.dtype(l.dtype).name, []).append(i)
    out = list(leaves)
    total_raw = total_wire = 0
    t_raw = t_ss = 0.0
    ok = True
    for name, idxs in groups.items():
        bucket = jnp.concatenate([leaves[i].reshape(-1) for i in idxs])
        msg = eng.encode(bucket, tensor_class=f"weight_{name}")
        rep = eng.transfer_times(msg, wire, codec_model=cm)
        total_raw += rep["raw_bytes"]
        total_wire += rep["wire_bytes"]
        t_raw += rep["t_raw"]
        t_ss += rep["t_split_send"]
        dec = eng.decode(msg)
        if bucket.dtype == jnp.bfloat16:
            ok &= bool(jnp.all(jax.lax.bitcast_convert_type(dec, jnp.uint16)
                               == jax.lax.bitcast_convert_type(bucket,
                                                               jnp.uint16)))
        else:
            ok &= bool(jnp.all(dec == bucket))
        off = 0
        for i in idxs:
            n = leaves[i].size
            out[i] = dec[off:off + n].reshape(leaves[i].shape)
            off += n
    return (jax.tree_util.tree_unflatten(treedef, out),
            dict(ratio=total_wire / total_raw, t_raw=t_raw, t_ss=t_ss,
                 exact=ok, raw_mb=total_raw / 2**20))


def main():
    mesh = make_smoke_mesh()
    cfg = configs.get_smoke("glm4_9b")
    tcfg = step_lib.TrainConfig(
        microbatches=1, policy=CompressionPolicy(min_bytes=0),
        optim=opt_lib.OptimConfig(lr=1e-3, warmup_steps=5))
    step, _ = step_lib.build_train_step(cfg, tcfg, mesh)
    state, _ = step_lib.build_train_state(cfg, tcfg, mesh,
                                          jax.random.PRNGKey(0))
    jstep = jax.jit(step, donate_argnums=(0,))
    batch = registry.make_batch(cfg, 4, 64)

    eng = Compressor(codec_name="packed")
    wire = WireModel(bandwidth=50e9)
    cm = CodecModel()  # paper-calibrated H200 codec rates for the model
    print("iter | loss   | weights MB | ratio | split-send gain | exact")
    rollout_params = None
    for it in range(3):
        for _ in range(5):  # "policy optimization" phase
            state, m = jstep(state, batch)
        rollout_params, rep = sync_weights(state["params"], eng, wire, cm)
        print(f"  {it:2d} | {float(m['loss']):.4f} | {rep['raw_mb']:8.1f}  "
              f"| {rep['ratio']:.3f} | {(rep['t_raw']/rep['t_ss']-1)*100:+6.1f}% "
              f"| {rep['exact']}")
    print("\nNOTE the smoke model's 0.2 MB is far below the paper's 1 MB "
          "compression threshold — the negative gain above is exactly WHY "
          "the policy gates on size (paper §5.1).")

    # the paper's headline tensor: gate_up_proj, 214 MB bf16
    big = jnp.asarray(
        np.random.default_rng(0).normal(0, 0.02, 214 * (1 << 20) // 2),
        jnp.bfloat16)
    msg = eng.encode(big, tensor_class="gate_up_proj")
    rep = eng.transfer_times(msg, wire, codec_model=cm)
    print(f"\npaper-scale tensor (214 MB, trained-weight stats): ratio "
          f"{rep['ratio']:.3f}, split-send gain "
          f"{(rep['t_raw']/rep['t_split_send']-1)*100:+.1f}% "
          f"(paper: +47.5% with ANS ratio 0.675; packed-wire ceiling is "
          f"1/ratio = +{(1/rep['ratio']-1)*100:.0f}%)")


if __name__ == "__main__":
    main()
