"""RL weight synchronization on the sync subsystem (paper §5.3.1, Fig. 10).

    PYTHONPATH=src python examples/rl_weight_sync.py

The paper's headline P2P workload: a trainer pushes updated policy weights
to rollout replicas every iteration.  This example drives it through
``src/repro/sync/`` end to end:

  * the trainer (a smoke-scale transformer twin) publishes a
    weight version after each optimization phase
    (``train/step.make_publish_hook``);
  * the schedule — per-dtype buckets, gates, full and XOR-delta codec
    widths — compiles ONCE into a kind-"wsync" ``CommPlan``; every later
    publish hits the plan cache;
  * each replica receives either a bitwise XOR delta against its acked
    base version (warm path — consecutive versions differ by small
    optimizer steps) or the full compressed tensors (first contact, late
    join, epoch fence, or delta-overflow fallback), and reconstructs the
    published weights BIT-EXACTLY either way;
  * "rollout-1" joins late to exercise the stale-base full-send fallback,
    and the final section fences an epoch (simulated trainer restart) to
    show acks being invalidated.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, sched
from repro.core import calibrate
from repro.core.policy import CompressionPolicy
from repro.launch.mesh import make_smoke_mesh
from repro.models import registry
from repro.optim import optimizers as opt_lib
from repro.sync import WeightSyncEngine, apply_update
from repro.train import step as step_lib


def bits_equal(a, b):
    from repro.core import codec

    def leaf_eq(x, y):
        lay = codec.LAYOUTS.get(jnp.dtype(x.dtype).name)
        if lay is not None:  # compare raw bits: NaN != NaN would lie here
            x = jax.lax.bitcast_convert_type(x, lay.uint_dtype)
            y = jax.lax.bitcast_convert_type(y, lay.uint_dtype)
        return bool(jnp.all(x == y))

    return all(jax.tree_util.tree_leaves(jax.tree.map(leaf_eq, a, b)))


def main():
    mesh = make_smoke_mesh()
    # smollm smoke twin keeps the CPU demo under 30 s; the paper's
    # GLM4-9B is the same code path at scale (configs.get_smoke("glm4_9b"))
    cfg = configs.get_smoke("smollm_135m")
    # KL-constrained RL fine-tuning moves weights gently: at this lr most
    # bf16 weights shift sub-ULP per optimizer step and round to NO bit
    # change — the regime the XOR-delta wire exploits (large lrs make the
    # deltas "cold" and the calibrated widths converge on the full wire).
    tcfg = step_lib.TrainConfig(
        microbatches=1, policy=CompressionPolicy(min_bytes=0),
        optim=opt_lib.OptimConfig(lr=1e-5, warmup_steps=3))
    step, _ = step_lib.build_train_step(cfg, tcfg, mesh)
    state, _ = step_lib.build_train_state(cfg, tcfg, mesh,
                                          jax.random.PRNGKey(0))
    jstep = jax.jit(step, donate_argnums=(0,))
    batch = registry.make_batch(cfg, 2, 64)

    # calibrate the delta-codec widths from one real publish-to-publish
    # delta (the paper's §3.4 offline-calibration story applied to the
    # delta wire): burn through lr warmup first — calibrating on the tiny
    # warmup steps would pick widths the steady-state deltas overflow —
    # then measure a delta at the actual publish cadence.  The jitted step
    # donates its input state, so snapshot the pre-phase weights.
    for _ in range(3):  # lr warmup burn-in
        state, _ = jstep(state, batch)
    v_prev = jax.tree.map(lambda l: l.copy(), state["params"])
    for _ in range(2):  # one publish cadence
        state, _ = jstep(state, batch)
    flat = lambda t: jnp.concatenate(
        [l.reshape(-1) for l in jax.tree_util.tree_leaves(t)])
    w_d, w_lo = calibrate.choose_delta_widths(flat(state["params"]),
                                              flat(v_prev))
    prof = calibrate.CompressionProfile(
        widths={"gradient": 5, "weight": 5, "activation": 5,
                "delta": w_d, "delta_lo": w_lo})
    plan_cache = sched.PlanCache()
    engine = WeightSyncEngine(
        policy=CompressionPolicy(min_bytes=0, profile=prof),
        plan_cache=plan_cache)
    publish = step_lib.make_publish_hook(engine)

    replicas = {"rollout-0": None}  # name -> replica-held params
    print(f"smollm smoke twin, delta widths exp={w_d}/lo={w_lo}; "
          f"rollout-1 joins at iter 1 (stale-base full-send fallback)")
    print("iter | loss   | replica   | mode  | wire KiB | vs raw | exact")
    for it in range(3):
        for _ in range(2):  # "policy optimization" phase
            state, m = jstep(state, batch)
        version = publish(state)
        if it == 1:
            replicas["rollout-1"] = None  # late joiner
        for name in sorted(replicas):
            upd = engine.update_for(name)
            held = replicas[name]
            new = apply_update(
                upd, base_params=held if upd.base_version is not None
                else None)
            replicas[name] = new
            engine.ack(name, upd.version, upd.epoch)
            exact = bits_equal(new, state["params"])
            assert exact, f"{name} diverged at v{version}"
            print(f"  {it:2d} | {float(m['loss']):.4f} | {name} | "
                  f"{upd.mode:5s} | {upd.wire_bytes/2**10:8.1f} | "
                  f"{upd.raw_bytes/max(upd.wire_bytes, 1):5.2f}x | {exact}")

    info = plan_cache.cache_info()
    print(f"\nwsync plan cache: {info['misses']} compile(s), "
          f"{info['hits']} hits — the schedule was decided once and "
          f"replayed for every broadcast (paper §3.3)")

    # epoch fencing: after a (simulated) trainer restart, version numbers
    # can repeat with different bits, so every outstanding ack is fenced
    # and the next send to EVERY replica goes out full.
    engine.advance_epoch()
    publish(state)
    upd = engine.update_for("rollout-0")
    assert upd.mode == "full" and upd.base_version is None
    replicas["rollout-0"] = apply_update(upd)
    assert bits_equal(replicas["rollout-0"], state["params"])
    print(f"epoch fence: post-restart update for rollout-0 is mode="
          f"{upd.mode} (acks invalidated), reconstructed bit-exact")


if __name__ == "__main__":
    main()
