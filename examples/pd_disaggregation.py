"""Prefill-decode disaggregation with compressed KV transfer (paper §5.3.2).

    PYTHONPATH=src python examples/pd_disaggregation.py

P1D3 layout: one "prefill worker" fills KV caches, three "decode workers"
generate.  The KV cache crosses the wire through the host P2P engine
(pack_cache/unpack_cache) with lossless compression; generation on the
decode side is verified identical to a colocated run."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer
from repro.p2p.engine import Compressor, WireModel
from repro.serve.kv_transfer import pack_cache, unpack_cache


def greedy_decode(params, cfg, cache, first_tok, n, enc_out=None):
    toks = [int(first_tok[0, 0])]
    cur = first_tok
    for _ in range(n - 1):
        logits, cache = transformer.decode_step(params, cur, cache, cfg,
                                                enc_out=enc_out)
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(cur[0, 0]))
    return toks, cache


def main():
    cfg = configs.get_smoke("tinyllama_1_1b")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    eng = Compressor(codec_name="packed")
    wire = WireModel(bandwidth=50e9)
    rng = np.random.default_rng(0)
    max_len = 192

    prompts = [rng.integers(0, cfg.vocab, 96).astype(np.int32)
               for _ in range(3)]
    print("P1D3: 1 prefill worker, 3 decode workers; 96-token prompts, "
          "16 new tokens each\n")
    for d, prompt in enumerate(prompts):
        # ---- prefill worker ----
        cache = transformer.init_cache(cfg, 1, max_len)
        logits, cache = transformer.prefill(
            params, {"tokens": jnp.asarray(prompt[None])}, cfg, cache)
        first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)

        # ---- KV transfer (compressed) ----
        t0 = time.perf_counter()
        pkg = pack_cache(cache, eng)
        t_pack = time.perf_counter() - t0
        raw_b = sum(np.asarray(l).nbytes
                    for l in jax.tree_util.tree_leaves(cache))
        wire_b = sum(m.wire_bytes() if hasattr(m, "wire_bytes")
                     else np.asarray(m).nbytes for m in pkg["messages"])
        cache_dec = unpack_cache(pkg, eng)

        # ---- decode worker d ----
        toks_d, _ = greedy_decode(params, cfg, cache_dec, first, 16)
        # ---- colocated reference ----
        toks_ref, _ = greedy_decode(params, cfg, cache, first, 16)
        same = toks_d == toks_ref
        print(f"decode worker {d}: cache {raw_b/2**20:5.2f} MiB -> "
              f"{wire_b/2**20:5.2f} MiB (ratio {wire_b/raw_b:.3f}), "
              f"modelled latency cut {(1-wire_b/raw_b)*100:4.1f}%, "
              f"tokens match colocated: {same}")
        assert same, "PD-disaggregated generation must be bit-identical"
    print("\npaper: up to 30.1% KV-transfer latency cut (P1D3, vLLM) -> "
          "~10% end-to-end; transfer here is verified lossless")


if __name__ == "__main__":
    main()
