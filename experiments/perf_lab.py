"""Perf hillclimbing lab: lower named VARIANTS of a cell, emit the 3-term
roofline for each, and diff against the baseline.

    PYTHONPATH=src python experiments/perf_lab.py --cell smollm_135m:train_4k \
        --variants baseline,raw,width4,ring

Each variant re-lowers the full step on the production mesh and reports
compute/memory/collective terms + per-device temp memory, so a hypothesis →
change → measure cycle is one invocation (EXPERIMENTS.md §Perf logs these).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import configs
from repro.core.calibrate import CompressionProfile
from repro.core.policy import CompressionPolicy
from repro.launch import cells as cells_lib
from repro.launch.dryrun import build_step_fn, input_specs, make_train_config
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                                     Roofline, model_flops_for)
from repro.roofline.model import analytic_cost, collective_bytes_trip_aware
from repro.train import step as step_lib


def lower_cell(arch, shape_name, mesh, *, tcfg=None, serve_tweaks=None,
               compressed=True):
    from repro.launch import dryrun as dr
    cfg = configs.get(arch)
    shape = cells_lib.SHAPES[shape_name]
    if shape.kind == "train":
        tcfg = tcfg or make_train_config(arch, mesh, compressed=compressed)
        step, _ = step_lib.build_train_step(cfg, tcfg, mesh)
        state, _ = step_lib.abstract_train_state(cfg, tcfg, mesh)
        batch = dr._batch_structs(cfg, mesh, shape.global_batch,
                                  shape.seq_len,
                                  dp=step_lib.dp_axes_of(mesh))
        args = (state, batch)
        donate = (0,)
    else:
        step, donate = build_step_fn(arch, shape_name, mesh,
                                     compressed=compressed)
        args = input_specs(arch, shape_name, mesh)
    with mesh:
        t0 = time.time()
        compiled = jax.jit(step, donate_argnums=donate).lower(*args).compile()
        dt = time.time() - t0
    return compiled, dt


def analyze(compiled, arch, shape_name, mesh_kind, *, micro_remat=None):
    mem = compiled.memory_analysis()
    coll = collective_bytes_trip_aware(compiled.as_text())
    n_chips = 512 if mesh_kind == "multi" else 256
    ac = analytic_cost(arch, shape_name, mesh_kind, micro_remat=micro_remat)
    r = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_kind,
        flops=ac.total_flops / n_chips,
        hbm_bytes=ac.hbm_bytes_per_device,
        coll_bytes=float(coll["total_bytes"]),
        model_flops=ac.model_flops,
        n_chips=n_chips)
    return r, mem, coll


def make_variant(name, arch, mesh):
    """Named variants = the hillclimb levers."""
    base = make_train_config(arch, mesh)
    prof = base.policy.profile
    V = {
        "baseline": dict(tcfg=base),
        "raw": dict(tcfg=make_train_config(arch, mesh, compressed=False),
                    compressed=False),
        "width4": dict(tcfg=dataclasses.replace(base, policy=CompressionPolicy(
            profile=dataclasses.replace(
                prof, widths={k: 4 for k in prof.widths})))),
        "width6": dict(tcfg=dataclasses.replace(base, policy=CompressionPolicy(
            profile=dataclasses.replace(
                prof, widths={k: 6 for k in prof.widths})))),
        "block1k": dict(tcfg=dataclasses.replace(base, policy=CompressionPolicy(
            profile=dataclasses.replace(prof, block=1024)))),
        "block2k": dict(tcfg=dataclasses.replace(base, policy=CompressionPolicy(
            profile=dataclasses.replace(prof, block=2048)))),
        "ring": dict(tcfg=dataclasses.replace(base, policy=CompressionPolicy(
            allreduce_algorithm="ring", profile=prof))),
        "micro_half": dict(tcfg=dataclasses.replace(
            base, microbatches=max(1, base.microbatches // 2))),
        "micro_double": dict(tcfg=dataclasses.replace(
            base, microbatches=base.microbatches * 2)),
        "no_guard": dict(tcfg=dataclasses.replace(base,
                                                  guard_overflow=False)),
        "losschunk512": dict(tcfg=dataclasses.replace(base, loss_chunk=512)),
        "losschunk2k": dict(tcfg=dataclasses.replace(base, loss_chunk=2048)),
        "dp_only": dict(tcfg=make_train_config(arch, mesh, dp_only=True)),
        "dp_only_raw": dict(tcfg=make_train_config(
            arch, mesh, dp_only=True, compressed=False)),
        "dp_only_w4": dict(tcfg=dataclasses.replace(
            make_train_config(arch, mesh, dp_only=True),
            policy=CompressionPolicy(profile=dataclasses.replace(
                prof, widths={k: 4 for k in prof.widths})))),
        "dp_only_noremat": dict(tcfg=dataclasses.replace(
            make_train_config(arch, mesh, dp_only=True), remat=False)),
        "noremat": dict(tcfg=dataclasses.replace(base, remat=False)),
    }
    return V[name]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variants", default="baseline,raw")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    out = []
    print(f"cell {args.cell} on {args.mesh} mesh")
    print(f"{'variant':14s} {'compute ms':>10s} {'memory ms':>10s} "
          f"{'coll ms':>9s} {'bound':>11s} {'temp GiB':>9s} "
          f"{'roofl-frac':>10s} {'compile s':>9s}")
    for vname in args.variants.split(","):
        try:
            kw = make_variant(vname, arch, mesh) if shape == "train_4k" or \
                cells_lib.SHAPES[shape].kind == "train" else (
                dict(compressed=(vname != "raw")))
            compiled, dt = lower_cell(arch, shape, mesh, **kw)
            tc = kw.get("tcfg")
            mr = (tc.microbatches > 1) if tc is not None else None
            r, mem, coll = analyze(compiled, arch, shape, args.mesh,
                                   micro_remat=mr)
            if tc is not None and not tc.remat:
                # layer remat off: subtract the replay fwd-equivalent
                from repro.roofline.model import analytic_cost
                ac = analytic_cost(arch, shape, args.mesh, micro_remat=mr)
                scale = (ac.total_flops - ac.model_flops / 3) / ac.total_flops
                r = dataclasses.replace(r, flops=r.flops * scale)
            temp = (mem.temp_size_in_bytes or 0) / 2**30
            print(f"{vname:14s} {r.t_compute*1e3:10.2f} {r.t_memory*1e3:10.2f} "
                  f"{r.t_collective*1e3:9.2f} {r.bottleneck:>11s} "
                  f"{temp:9.2f} {r.roofline_fraction:10.3f} {dt:9.1f}")
            out.append(dict(variant=vname, t_compute=r.t_compute,
                            t_memory=r.t_memory, t_collective=r.t_collective,
                            bottleneck=r.bottleneck, temp_gib=temp,
                            roofline_fraction=r.roofline_fraction,
                            coll_by_kind=coll["bytes"],
                            coll_counts=coll["counts"]))
        except Exception as e:
            print(f"{vname:14s} FAILED {type(e).__name__}: {str(e)[:120]}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
